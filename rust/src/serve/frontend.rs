//! The admission frontend stage: tenant connections never wait on the
//! scheduler loop.
//!
//! The paper's late-binding story only holds if *admission* is late-bound
//! too: a tenant's accept/reject must not stall behind a full
//! issue/launch/collect iteration of the scheduler thread (the
//! early-binding head-of-line coupling §3 argues against). This module
//! splits admission into its own pipeline stage:
//!
//! ```text
//!  generator ──Incoming──▶ frontend thread ──Admitted──▶ scheduler loop
//!  (clients)               (owns the gate)               (owns the JIT)
//!                              ▲                             │
//!                              └──── AdmissionView ◀─────────┘
//!                                    (published snapshot)
//! ```
//!
//! **Threading model / queue ownership.**
//!
//! * The *generator* (client side) owns nothing: it sends `Incoming`
//!   requests into the intake MPSC channel and never blocks on serving
//!   state.
//! * The *frontend thread* owns the intake receiver, the admission gate
//!   ([`FrontendGate`]: the bounded-queue policy plus the cumulative
//!   accept counters), and the (tenant, model) → stream interning table.
//!   It prices every request against the latest published
//!   [`AdmissionView`] — never against live scheduler state — so a
//!   decision costs a snapshot load plus arithmetic, bounded regardless
//!   of what the scheduler thread is doing. Accepted requests flow to the
//!   scheduler as pre-priced `Admitted` records; rejects turn around to
//!   the client without ever touching the scheduler thread.
//! * The *scheduler thread* owns the JIT (window, clock, launch stage)
//!   and the accepted-requests receiver. Once per loop iteration — after
//!   draining accepted requests, issuing launches, and folding in
//!   completions — it publishes a fresh `AdmissionView` through the
//!   shared [`ViewCell`]. Publication order (snapshot built *after* the
//!   iteration's submits and completions, `seq` monotonically increasing)
//!   means a view can only ever lag reality, never lead it.
//!
//! **Staleness is safe by construction.** Between publications the
//! frontend keeps accepting against an old snapshot, so it tracks its own
//! cumulative accept counts per group and per stream; the scheduler
//! publishes how many of those it has drained into the window. The
//! difference — requests still in the accepted channel — is added to the
//! snapshot's queue depth before every decision, so the gate can never
//! admit more outstanding work than `max_queue` no matter how stale the
//! view is (pinned by `prop_stale_view_never_over_admits`). Estimate
//! staleness errs the same way: the in-flight drain term was computed at
//! publish time, before some execution elapsed, so a stale view
//! *over*-prices the drain and sheds extra rather than over-admitting.
//!
//! **Where the prices come from.** Every `est_by_n` table in a snapshot
//! is sampled from the ONE tiered cost model ([`crate::estimate`]) via
//! [`ServeExecutor::estimate_group_table_us`]: a Measured EWMA when the
//! (class, group, padded-batch) variant has real observations, a
//! warm-started Tuned artifact-cache entry before the first observation
//! lands, and the analytic Prior otherwise. The frontend itself never
//! re-estimates — it prices against whatever tier answered at publish
//! time. When a variant *changes answering tier* (a Tuned warm-start
//! overtaken by its first real Measurement) without a completion in the
//! same engine iteration, the estimator's generation counter forces the
//! next snapshot publication, so a memoized `est_by_n` table can go stale
//! for at most one publish interval (see `Engine::settle`).
//!
//! **One frontend thread, not a pool.** Per-stream program order is the
//! order requests enter the window, which is the order the frontend
//! forwards them. A pool would need to shard the intake by stream hash to
//! preserve that; today's single thread decides in well under a
//! microsecond, so sharding is deferred until admission itself measures
//! hot (`ServeMetrics::admission_latency` is the histogram to watch).
//!
//! **Bookkeeping bound.** The gate's interning table and cumulative
//! per-stream accept counters (and the scheduler's mirrored drain
//! counters, copied into each snapshot) are compacted *epoch-wise*:
//! every [`FRONTEND_EPOCH_US`] the frontend thread calls
//! [`FrontendGate::advance_epoch`], which retires every stream that (a)
//! saw no gate activity for the full elapsed epoch and (b) whose accepts
//! the scheduler has fully drained (`accepted == drained` against the
//! latest snapshot — nothing of the stream's is still in the accepted
//! channel). Retired ids go to the scheduler as a `Retire` record on the
//! accepted channel (ordered after any prior accepts, so it can never
//! overtake one), and the scheduler drops its mirrored drain counter.
//! Stream ids are **never reused**: a retired (tenant, model) pair that
//! returns is interned as a fresh id, which matches the window's own
//! fully-drained-stream-restarts-clean semantics. Bookkeeping is thus
//! bounded by the *live* stream set under tenant churn, not by every
//! pair ever served (pinned by
//! `frontend_bookkeeping_bounded_under_tenant_churn`, the gate-side
//! mirror of the window's churn regression).
//!
//! **Why the `replay*` modes keep the synchronous gate.** The virtual-time
//! replays are deterministic: the clock only advances when the driver says
//! so, and admission happens at exact virtual arrival instants. A
//! wall-clock frontend thread would race the virtual clock and destroy
//! replay determinism (the property `replay_is_deterministic_*` pins), so
//! those drivers price requests through the *same* [`GroupView`] pricing
//! path, just built synchronously from live state — the two gates cannot
//! disagree on identical state (pinned by
//! `prop_admission_view_matches_sync_gate`).
//!
//! # The SLO-class contract at the gate
//!
//! Each [`GateRequest`] carries its tenant's [`SloClass`]; the shared
//! pricing path ([`GroupView::decide`] →
//! [`Admission::decide_class`](crate::serve::admission::Admission::decide_class))
//! is class-aware, so sync-gate/view equivalence holds *per class* (the
//! PR 4 property, re-pinned per class):
//!
//! - **Critical / Standard** keep the original pricing bit-for-bit.
//! - **Best-effort sheds first**: capped at a share of `max_queue`
//!   (`Admission::be_queue_share`), always shed once doomed, and — on the
//!   frontend path only — rejected outright while the published view is
//!   older than [`STALE_VIEW_US`] (a wedged scheduler sheds batch traffic
//!   before it prices anything optimistically; the sync gate never holds
//!   a stale view, so equivalence on identical fresh state is intact).
//! - **Rate-limit accounting**: per-tenant token buckets
//!   ([`TenantShaper`]) refill continuously at `rate/s` up to `burst`,
//!   clocked by the caller's `now_us` so the same shaper works under the
//!   wall and virtual clocks. A request that finds no token is rejected
//!   *before* pricing and counted as a per-tenant drop — shaped traffic
//!   never reaches the scheduler, which is what makes a saturating tenant
//!   invisible to everyone else's admission prices.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::compiler::ir::{SloClass, StreamId};
use crate::compiler::jit::JitCompiler;
use crate::serve::admission::{Admission, Admit};
use crate::serve::server::{ModelBackend, ServeExecutor};
use crate::util::stats::LatencyHist;

/// A decision made on a snapshot older than this counts as stale
/// (`ServeMetrics::stale_decisions`). The scheduler publishes at least
/// once per ~500µs drain tick when healthy, so staleness past 2ms means
/// the scheduler thread is wedged mid-iteration — exactly the condition
/// the frontend exists to ride out.
pub const STALE_VIEW_US: f64 = 2_000.0;

/// Counter-compaction epoch, µs of wall time. Once per epoch the frontend
/// thread retires every (tenant, model) stream that was idle for the full
/// elapsed epoch AND whose accepts the scheduler has fully drained (see
/// [`FrontendGate::advance_epoch`]); the scheduler then drops its
/// mirrored drain counter. Long enough that any launch in flight when the
/// stream went idle has long since completed; short enough that a
/// long-lived server under tenant churn stays bounded by its *live*
/// stream set.
pub const FRONTEND_EPOCH_US: f64 = 200_000.0;

/// Why a request was shed — the taxonomy carried on `FromFrontend`
/// rejection records and folded per class into
/// `ServeMetrics::rejects_by_reason`, so the wire intake can tell a
/// client *why* its op never reached the window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// The bounded-queue policy priced the request out (queue depth,
    /// doomed slack, unknown group, or a full window downstream).
    QueueFull,
    /// The tenant's token bucket had no token — shed before pricing.
    RateLimited,
    /// Best-effort shed outright because the published view was older
    /// than [`STALE_VIEW_US`] (frontend path only).
    StaleShed,
}

impl RejectReason {
    /// All reasons, in [`RejectReason::index`] order.
    pub const ALL: [RejectReason; 3] =
        [RejectReason::QueueFull, RejectReason::RateLimited, RejectReason::StaleShed];

    /// Dense index for per-reason counter arrays.
    pub fn index(self) -> usize {
        match self {
            RejectReason::QueueFull => 0,
            RejectReason::RateLimited => 1,
            RejectReason::StaleShed => 2,
        }
    }

    /// Wire/render name.
    pub fn name(self) -> &'static str {
        match self {
            RejectReason::QueueFull => "queue_full",
            RejectReason::RateLimited => "rate_limited",
            RejectReason::StaleShed => "stale_shed",
        }
    }
}

/// One request at the frontend gate: the pricing inputs that vary per
/// request (bundled so call sites cannot transpose adjacent scalars).
#[derive(Debug, Clone, Copy)]
pub struct GateRequest {
    /// Interned (tenant, model) stream.
    pub stream: StreamId,
    /// Independence of the stream's earlier requests (stateless serving).
    pub independent: bool,
    /// Absolute deadline, µs.
    pub deadline_us: f64,
    /// The issuing tenant's SLO class (class-aware admission).
    pub class: SloClass,
}

/// The frontend's accepted-but-not-yet-drained corrections folded into a
/// (possibly stale) view at decision time. All zero for the synchronous
/// gate, which always prices live state.
#[derive(Debug, Clone, Copy, Default)]
pub struct GateExtras {
    /// Group-level in-channel request count (accepted − drained).
    pub queued: u32,
    /// The requester's own stream's in-channel count.
    pub own: u32,
    /// Dependent-mode launch floor: max over the group's streams of
    /// (view depth + that stream's in-channel count). Without this, a
    /// burst accepted on *another* stream between publishes would be
    /// invisible to the launch-count bound and a stale view could
    /// under-price the drain — admitting what the sync gate sheds.
    pub max_depth: u32,
}

/// One group's admission-relevant state inside a published snapshot.
///
/// Also the synchronous gate's pricing structure: `Server::admit_request`
/// builds one of these from live JIT state and calls the same
/// [`GroupView::decide`], so the frontend and the synchronous path share
/// one pricing implementation by construction.
#[derive(Debug, Clone, Default)]
pub struct GroupView {
    /// Un-issued ops of the group in the window.
    pub pending: usize,
    /// Issued-but-unfinished ops of the group.
    pub inflight: usize,
    /// Per-launch pack-size cap (how many queued ops one launch drains).
    pub pack_cap: u32,
    /// `est_by_n[k]`: estimated service time of a (k+1)-op launch, µs,
    /// for k in `0..pack_cap` — the shared estimator sampled at publish.
    pub est_by_n: Vec<f64>,
    /// Undivided in-flight drain term at publish
    /// ([`JitCompiler::inflight_group_est_us`]): summed per-launch
    /// estimates with execution already elapsed subtracted from the
    /// launches actually executing.
    pub inflight_est_us: f64,
    /// Speed-weighted replica parallelism of the group's serving workers
    /// (1.0 for single-device drive modes) — the drain estimate's divisor.
    pub parallelism: f64,
    /// Measured backlog of the group's least-loaded serving worker, µs;
    /// replaces the in-flight term when device queues are observable.
    pub device_backlog_us: Option<f64>,
    /// Pending depth per stream with ops in this group (dependent-mode
    /// pricing: the max entry bounds the launch count, the requester's
    /// own entry extends it).
    pub stream_depths: Vec<(StreamId, usize)>,
}

impl GroupView {
    fn est_at(&self, n: u32) -> f64 {
        if self.est_by_n.is_empty() {
            return 0.0;
        }
        let i = (n.max(1) as usize - 1).min(self.est_by_n.len() - 1);
        self.est_by_n[i]
    }

    fn stream_depth(&self, stream: StreamId) -> usize {
        self.stream_depths
            .iter()
            .find(|(s, _)| *s == stream)
            .map(|(_, d)| *d)
            .unwrap_or(0)
    }

    /// Estimated drain ahead of one more request, µs. Covers both the
    /// un-issued queue and the group's in-flight launches, priced *per
    /// launch*: independent streams drain in ceil(queued / pack_cap)
    /// cap-wide launches; dependent streams expose one op per stream per
    /// launch, so the longest pending stream bounds the launch count
    /// (cross-stream coalescing still fills each launch). The whole drain
    /// is divided by the group's speed-weighted replica parallelism; the
    /// measured device backlog, when known, replaces the in-flight term.
    /// `extras` carries the frontend's accepted-but-not-yet-drained
    /// corrections (all zero for the synchronous gate).
    pub fn drain_est_us(
        &self,
        stream: StreamId,
        independent: bool,
        extras: GateExtras,
    ) -> f64 {
        let cap = self.pack_cap.max(1);
        let queued = self.pending as u32 + extras.queued + 1;
        let mut est = if independent {
            // cap-wide packs: full launches at the cap plus a remainder
            let full = queued / cap;
            let rem = queued % cap;
            f64::from(full) * self.est_at(cap)
                + if rem > 0 { self.est_at(rem) } else { 0.0 }
        } else {
            // program order binds: each launch takes at most one op per
            // stream, so the longest pending stream — counting this
            // request on its own stream — sets the launch count, while
            // cross-stream coalescing still packs each launch up to `cap`
            // wide across streams
            let own = self.stream_depth(stream) as u32 + extras.own + 1;
            let max_depth = self
                .stream_depths
                .iter()
                .map(|(_, d)| *d as u32)
                .max()
                .unwrap_or(0)
                .max(extras.max_depth);
            let launches = max_depth.max(own).max(queued.div_ceil(cap));
            let per_launch = queued.div_ceil(launches).min(cap).max(1);
            f64::from(launches) * self.est_at(per_launch)
        };
        // replicated groups drain their queue on several workers at once
        let parallelism = self.parallelism.max(1.0);
        est /= parallelism;
        est += match self.device_backlog_us {
            // device timelines known: the least-loaded replica's queued
            // work is the true wait (already per-worker, not divided)
            Some(backlog) => backlog,
            None => self.inflight_est_us / parallelism,
        };
        est
    }

    /// The gate decision on this state — the ONE implementation behind
    /// both the synchronous gate and the frontend stage. Class-aware:
    /// the drain estimate is identical for every class (one queue, one
    /// price), the *decision* on it is per class
    /// ([`Admission::decide_class`]).
    pub fn decide(
        &self,
        admission: &Admission,
        req: &GateRequest,
        extras: GateExtras,
        now_us: f64,
    ) -> Admit {
        let est = self.drain_est_us(req.stream, req.independent, extras);
        let slack = req.deadline_us - now_us - est;
        admission.decide_class(
            req.class,
            self.pending + extras.queued as usize,
            self.inflight,
            slack,
        )
    }
}

/// Build one group's snapshot from live scheduler state. Used both to
/// publish [`AdmissionView`]s (frontend path, `with_depths = true`) and,
/// per request, by the synchronous gate — so the two paths price through
/// identical inputs. Synchronous *independent-mode* callers pass
/// `with_depths = false` to skip the per-stream window scan their
/// pricing never reads; the estimate table is memoized per padded
/// variant either way ([`ServeExecutor::estimate_group_table_us`]).
pub fn snapshot_group<B: ModelBackend>(
    jit: &JitCompiler<ServeExecutor<B>, Vec<f32>>,
    group: u64,
    parallelism: f64,
    device_backlog_us: Option<f64>,
    with_depths: bool,
) -> GroupView {
    let cap = jit.pack_cap(group).max(1) as u32;
    GroupView {
        pending: jit.window.pending_in_group(group),
        inflight: jit.window.inflight_in_group(group),
        pack_cap: cap,
        est_by_n: jit.executor().estimate_group_table_us(group, cap),
        inflight_est_us: jit
            .inflight_group_est_us(group, parallelism.max(1.0).round() as u32),
        parallelism,
        device_backlog_us,
        stream_depths: if with_depths {
            jit.window.stream_depths_in_group(group)
        } else {
            Vec::new()
        },
    }
}

/// The scheduler-state snapshot the frontend prices against, published
/// atomically once per scheduler iteration.
#[derive(Debug, Clone)]
pub struct AdmissionView {
    /// Monotonic publication number.
    pub seq: u64,
    /// Scheduler clock at publish, µs — diagnostic only: the wall-clock
    /// frontend prices with its own clock (`t0.elapsed()`), which can
    /// only be *ahead* of this, so estimates err toward shedding.
    pub now_us: f64,
    /// Wall time of publication (staleness accounting).
    pub published: Instant,
    /// Per-group state, indexed by group id.
    pub groups: Vec<GroupView>,
    /// Cumulative accepted requests the scheduler has drained into the
    /// window, per group. The frontend subtracts this from its own
    /// cumulative accept count to price requests still in flight between
    /// the two threads.
    pub drained: Vec<u64>,
    /// The same cumulative drain count per stream id (dependent-mode
    /// own-stream pricing). Sparse: entries for retired streams are
    /// dropped when the gate compacts them (ids are never reused, so a
    /// missing entry always means zero-or-retired, never a collision).
    pub drained_by_stream: BTreeMap<u32, u64>,
}

/// Single-writer, multi-reader publication cell for [`AdmissionView`]s.
///
/// `Mutex<Arc<_>>` rather than a bespoke lock-free cell on purpose: the
/// critical section on either side is one pointer clone/swap — no
/// allocation, no I/O, no waiting on scheduler work — so the frontend can
/// never block behind a scheduler iteration, which is the property the
/// whole stage exists for. The scheduler allocates the new snapshot
/// *outside* the lock and only swaps the `Arc` inside it.
pub struct ViewCell {
    view: Mutex<Arc<AdmissionView>>,
}

impl ViewCell {
    /// New cell holding an initial snapshot.
    pub fn new(initial: AdmissionView) -> Arc<Self> {
        Arc::new(ViewCell {
            view: Mutex::new(Arc::new(initial)),
        })
    }

    /// Swap in a fresh snapshot (scheduler thread, once per iteration).
    pub fn publish(&self, v: AdmissionView) {
        *self.view.lock().expect("view cell poisoned") = Arc::new(v);
    }

    /// Load the latest snapshot (frontend thread, per decision).
    pub fn load(&self) -> Arc<AdmissionView> {
        Arc::clone(&self.view.lock().expect("view cell poisoned"))
    }
}

/// The frontend thread's gate state: the bounded-queue policy, the stream
/// interning table, and the cumulative accept counters that make stale
/// snapshots safe (see the module docs).
pub struct FrontendGate {
    admission: Admission,
    /// (tenant, group) → interned stream id, first-appearance order.
    /// Retired entries are removed; their ids are never reused
    /// (`next_stream` only grows), so a returning pair gets a fresh id.
    streams: BTreeMap<(u32, u64), u32>,
    /// Next stream id to hand out (monotonic — survives retirement).
    next_stream: u32,
    /// Cumulative accepts per group (bounded by the model table; never
    /// compacted).
    accepted: Vec<u64>,
    /// Cumulative accepts per live stream id (sparse; compacted).
    accepted_by_stream: BTreeMap<u32, u64>,
    /// Each live stream's (single, fixed) group — the dependent-mode
    /// launch floor scans only the request's group.
    stream_group: BTreeMap<u32, u64>,
    /// Streams touched (interned or decided) since the last epoch sweep.
    active: BTreeSet<u32>,
}

impl FrontendGate {
    /// New gate over `groups` model groups.
    pub fn new(admission: Admission, groups: usize) -> Self {
        FrontendGate {
            admission,
            streams: BTreeMap::new(),
            next_stream: 0,
            accepted: vec![0; groups],
            accepted_by_stream: BTreeMap::new(),
            stream_group: BTreeMap::new(),
            active: BTreeSet::new(),
        }
    }

    /// Intern the (tenant, group) pair as a stream, ids in
    /// first-appearance order (monotonic across retirements).
    pub fn intern(&mut self, tenant: u32, group: u64) -> StreamId {
        let id = match self.streams.get(&(tenant, group)) {
            Some(id) => *id,
            None => {
                let id = self.next_stream;
                self.next_stream += 1;
                self.streams.insert((tenant, group), id);
                id
            }
        };
        self.ensure_stream(id, group);
        self.active.insert(id);
        StreamId(id)
    }

    fn ensure_stream(&mut self, s: u32, group: u64) {
        self.accepted_by_stream.entry(s).or_insert(0);
        self.stream_group.insert(s, group);
    }

    /// Live (tenant, model) streams currently tracked — the churn bound.
    pub fn tracked_streams(&self) -> usize {
        self.streams.len()
    }

    /// Accepted-but-not-yet-drained request count for a group: the work
    /// in the accepted channel the snapshot cannot see yet.
    fn in_channel(&self, view: &AdmissionView, group: u64) -> u64 {
        let a = self.accepted.get(group as usize).copied().unwrap_or(0);
        let d = view.drained.get(group as usize).copied().unwrap_or(0);
        a.saturating_sub(d)
    }

    /// A stream's accepted-but-not-yet-drained count against this view.
    fn in_channel_of_stream(&self, view: &AdmissionView, s: u32) -> u32 {
        let a = self.accepted_by_stream.get(&s).copied().unwrap_or(0);
        let d = view.drained_by_stream.get(&s).copied().unwrap_or(0);
        a.saturating_sub(d) as u32
    }

    /// Dependent-mode launch floor: max over the group's known streams of
    /// (view depth + in-channel count). A burst accepted on another
    /// stream between publishes deepens that stream's run even though the
    /// stale view cannot see it yet — without this, the gate would
    /// under-price the serial drain the sync gate charges.
    fn dependent_max_depth(&self, view: &AdmissionView, gv: &GroupView, group: u64) -> u32 {
        self.stream_group
            .iter()
            .filter(|(_, g)| **g == group)
            .map(|(s, _)| {
                gv.stream_depth(StreamId(*s)) as u32
                    + self.in_channel_of_stream(view, *s)
            })
            .max()
            .unwrap_or(0)
    }

    /// Epoch boundary: retire every stream that was idle (no intern/
    /// decide) for the whole elapsed epoch AND whose accepts the
    /// scheduler has fully drained against `view` — its interning entry
    /// and accept counter are dropped, and the returned ids tell the
    /// scheduler to drop its mirrored drain counters. In-channel work
    /// blocks retirement, so a `Retire` record can never overtake a
    /// still-queued accept of the same stream. Ids are never reused; a
    /// retired pair that returns is interned fresh, mirroring the
    /// window's fully-drained-stream-restarts-clean semantics.
    pub fn advance_epoch(&mut self, view: &AdmissionView) -> Vec<u32> {
        // candidate set = every tracked per-stream entry, NOT just the
        // interned ids: decide()'s grow-on-demand path can create counter
        // entries for stream ids interned elsewhere, and those must be
        // subject to the same retirement or the bookkeeping bound leaks
        let retired: Vec<u32> = self
            .accepted_by_stream
            .keys()
            .copied()
            .filter(|s| !self.active.contains(s) && self.in_channel_of_stream(view, *s) == 0)
            .collect();
        if !retired.is_empty() {
            let dead: BTreeSet<u32> = retired.iter().copied().collect();
            self.streams.retain(|_, s| !dead.contains(s));
            for s in &dead {
                self.accepted_by_stream.remove(s);
                self.stream_group.remove(s);
            }
        }
        self.active.clear();
        retired
    }

    /// Decide one request against the latest snapshot. On Accept the
    /// gate's cumulative counters advance, so subsequent decisions on the
    /// same (stale) view already price this request as queued.
    pub fn decide(
        &mut self,
        view: &AdmissionView,
        group: u64,
        req: &GateRequest,
        now_us: f64,
    ) -> Admit {
        match self.decide_reason(view, group, req, now_us) {
            None => Admit::Accept,
            Some(_) => Admit::Reject,
        }
    }

    /// [`FrontendGate::decide`] with the shed taxonomy attached: `None`
    /// is an accept, `Some(reason)` says why the request was turned away
    /// — what the frontend stage stamps on its `FromFrontend` rejection
    /// records so the wire intake can answer the client honestly.
    pub fn decide_reason(
        &mut self,
        view: &AdmissionView,
        group: u64,
        req: &GateRequest,
        now_us: f64,
    ) -> Option<RejectReason> {
        let Some(gv) = view.groups.get(group as usize) else {
            return Some(RejectReason::QueueFull);
        };
        let s = req.stream.0;
        self.active.insert(s);
        // best-effort sheds first under a stale view: a wedged scheduler
        // means every price in the snapshot is optimistic — batch traffic
        // absorbs the uncertainty so latency classes keep today's pricing
        if req.class == SloClass::BestEffort
            && view.published.elapsed().as_secs_f64() * 1e6 > STALE_VIEW_US
        {
            return Some(RejectReason::StaleShed);
        }
        let extras = GateExtras {
            queued: self.in_channel(view, group) as u32,
            own: self.in_channel_of_stream(view, s),
            // only dependent pricing reads the floor; skip the scan for
            // the (default) independent mode
            max_depth: if req.independent {
                0
            } else {
                self.dependent_max_depth(view, gv, group)
            },
        };
        let d = gv.decide(&self.admission, req, extras, now_us);
        if d == Admit::Accept {
            if let Some(a) = self.accepted.get_mut(group as usize) {
                *a += 1;
            }
            // grow on demand: callers may price streams interned elsewhere
            self.ensure_stream(s, group);
            *self.accepted_by_stream.entry(s).or_insert(0) += 1;
            None
        } else {
            Some(RejectReason::QueueFull)
        }
    }
}

/// A continuously-refilling token bucket, clocked by the caller's `now_us`
/// so the same shaper works under both the wall and virtual clocks.
#[derive(Debug, Clone)]
pub struct TokenBucket {
    /// Refill rate, tokens (= requests) per second.
    pub rate_per_s: f64,
    /// Bucket capacity (burst allowance), tokens.
    pub burst: f64,
    tokens: f64,
    last_us: f64,
}

impl TokenBucket {
    /// New bucket, born full (a tenant's first burst is always admitted).
    pub fn new(rate_per_s: f64, burst: f64) -> Self {
        TokenBucket {
            rate_per_s,
            burst: burst.max(1.0),
            tokens: burst.max(1.0),
            last_us: 0.0,
        }
    }

    /// Take one token at `now_us`; false = rate-limited. Time only ever
    /// credits forward (a reordered timestamp never drains the bucket).
    pub fn try_take(&mut self, now_us: f64) -> bool {
        let dt = (now_us - self.last_us).max(0.0);
        self.last_us = self.last_us.max(now_us);
        self.tokens = (self.tokens + dt * self.rate_per_s / 1e6).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Per-tenant traffic shaping: one token bucket per configured tenant.
/// Tenants without a limit pass unshaped. Shared by the synchronous gate
/// and the frontend stage (whichever owns admission owns the shaper).
#[derive(Debug, Clone, Default)]
pub struct TenantShaper {
    buckets: BTreeMap<u32, TokenBucket>,
}

impl TenantShaper {
    /// A shaper over a tenant → (rate_per_s, burst) table — how the
    /// engine hands the same limits to whichever gate owns admission.
    pub fn from_rates(rates: &BTreeMap<u32, (f64, f64)>) -> Self {
        let mut s = TenantShaper::default();
        for (&tenant, &(rate_per_s, burst)) in rates {
            s.set_limit(tenant, rate_per_s, burst);
        }
        s
    }

    /// Limit `tenant` to `rate_per_s` requests/s with a `burst` allowance.
    pub fn set_limit(&mut self, tenant: u32, rate_per_s: f64, burst: f64) {
        self.buckets.insert(tenant, TokenBucket::new(rate_per_s, burst));
    }

    /// True when no tenant is shaped (the common single-class setup).
    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }

    /// Admit or rate-limit one request from `tenant` at `now_us`.
    pub fn admit(&mut self, tenant: u32, now_us: f64) -> bool {
        match self.buckets.get_mut(&tenant) {
            Some(b) => b.try_take(now_us),
            None => true,
        }
    }
}

/// What the frontend thread hands back at shutdown, merged into the run's
/// `ServeMetrics` by the scheduler thread.
#[derive(Debug, Default)]
pub struct FrontendReport {
    /// Rejected requests per tenant.
    pub drops: BTreeMap<u32, u64>,
    /// Arrival → gate-decision latency, µs.
    pub admission_latency: LatencyHist,
    /// Decisions made.
    pub decisions: u64,
    /// Decisions made on a snapshot older than [`STALE_VIEW_US`].
    pub stale_decisions: u64,
    /// Accepts per SLO class, indexed by [`SloClass::index`].
    pub accepts_by_class: [u64; 3],
    /// Rejects per SLO class (shaped requests included).
    pub rejects_by_class: [u64; 3],
    /// Requests the per-tenant token bucket turned away before pricing
    /// (a subset of `rejects_by_class`).
    pub shaped_by_class: [u64; 3],
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gview(pending: usize, inflight: usize) -> GroupView {
        GroupView {
            pending,
            inflight,
            pack_cap: 4,
            est_by_n: vec![100.0, 150.0, 200.0, 250.0],
            inflight_est_us: 0.0,
            parallelism: 1.0,
            device_backlog_us: None,
            stream_depths: Vec::new(),
        }
    }

    fn view(g: GroupView) -> AdmissionView {
        AdmissionView {
            seq: 1,
            now_us: 0.0,
            published: Instant::now(),
            groups: vec![g],
            drained: vec![0],
            drained_by_stream: BTreeMap::new(),
        }
    }

    fn req(stream: u32, deadline_us: f64) -> GateRequest {
        GateRequest {
            stream: StreamId(stream),
            independent: true,
            deadline_us,
            class: SloClass::Standard,
        }
    }

    #[test]
    fn independent_drain_prices_full_and_remainder_launches() {
        let g = gview(5, 0);
        // queued = 6: one cap-wide (4-op) launch + a 2-op remainder
        let est = g.drain_est_us(StreamId(0), true, GateExtras::default());
        assert!((est - (250.0 + 150.0)).abs() < 1e-9, "est {est}");
    }

    #[test]
    fn dependent_drain_bounded_by_longest_stream() {
        let mut g = gview(3, 0);
        g.stream_depths = vec![(StreamId(7), 3)];
        // all 3 pending on stream 7; a 4th on the same stream drains in 4
        // serial launches of 1 op each
        let est = g.drain_est_us(StreamId(7), false, GateExtras::default());
        assert!((est - 4.0 * 100.0).abs() < 1e-9, "est {est}");
        // a different stream still needs max-stream-depth launches, each
        // wide enough to carry the cross-stream queue
        let est2 = g.drain_est_us(StreamId(8), false, GateExtras::default());
        assert!((est2 - 3.0 * 150.0).abs() < 1e-9, "est2 {est2}");
    }

    #[test]
    fn device_backlog_replaces_inflight_term() {
        let mut g = gview(0, 2);
        g.inflight_est_us = 10_000.0;
        g.device_backlog_us = Some(300.0);
        let est = g.drain_est_us(StreamId(0), true, GateExtras::default());
        assert!((est - (100.0 + 300.0)).abs() < 1e-9, "est {est}");
    }

    #[test]
    fn stale_view_prices_other_streams_dependent_bursts() {
        // dependent mode: a burst accepted on stream A between publishes
        // is invisible in the frozen view's stream_depths, but the gate's
        // own counters must still raise the launch floor for a later
        // stream-B request — staleness may only shed extra, never admit
        // what the sync gate would shed
        let v = view(gview(0, 0));
        let mut gate = FrontendGate::new(Admission::new(64), 1);
        let a = gate.intern(0, 0);
        let b = gate.intern(1, 0);
        let dep = |stream: StreamId, deadline_us: f64| GateRequest {
            stream,
            independent: false,
            deadline_us,
            class: SloClass::Standard,
        };
        for _ in 0..6 {
            assert_eq!(gate.decide(&v, 0, &dep(a, 1e9), 0.0), Admit::Accept);
        }
        // B's drain: A's accepted run of 6 binds 6 serial launches, each
        // ~2 wide (7 queued / 6 launches) → 6·150 = 900µs. Without the
        // floor the stale view would price ~2 launches (500µs) and admit.
        assert_eq!(
            gate.decide(&v, 0, &dep(b, 800.0), 0.0),
            Admit::Reject,
            "stale view must not under-price another stream's burst"
        );
        assert_eq!(gate.decide(&v, 0, &dep(b, 1_000.0), 0.0), Admit::Accept);
    }

    #[test]
    fn gate_counts_in_channel_work_against_stale_views() {
        let v = view(gview(0, 0));
        let mut gate = FrontendGate::new(Admission::new(3), 1);
        // the view never refreshes; the gate's own counters must bound
        // outstanding work at max_queue
        let mut accepts = 0;
        for t in 0..10u32 {
            let stream = gate.intern(t, 0);
            if gate.decide(&v, 0, &req(stream.0, 1e9), 0.0) == Admit::Accept {
                accepts += 1;
            }
        }
        assert_eq!(accepts, 3, "stale view must not over-admit");
    }

    #[test]
    fn gate_reconciles_drained_counts() {
        let mut gate = FrontendGate::new(Admission::new(2), 1);
        let v0 = view(gview(0, 0));
        let s = gate.intern(0, 0);
        assert_eq!(gate.decide(&v0, 0, &req(s.0, 1e9), 0.0), Admit::Accept);
        assert_eq!(gate.decide(&v0, 0, &req(s.0, 1e9), 0.0), Admit::Accept);
        assert_eq!(gate.decide(&v0, 0, &req(s.0, 1e9), 0.0), Admit::Reject);
        // the scheduler drained both, completed them, and published: the
        // in-channel count returns to zero and room opens up again
        let mut v1 = view(gview(0, 0));
        v1.drained = vec![2];
        v1.drained_by_stream = BTreeMap::from([(s.0, 2)]);
        assert_eq!(gate.decide(&v1, 0, &req(s.0, 1e9), 0.0), Admit::Accept);
    }

    #[test]
    fn unknown_group_rejects() {
        let v = view(gview(0, 0));
        let mut gate = FrontendGate::new(Admission::default(), 1);
        assert_eq!(gate.decide(&v, 9, &req(0, 1e9), 0.0), Admit::Reject);
    }

    #[test]
    fn view_cell_publishes_latest() {
        let mut v = view(gview(0, 0));
        let cell = ViewCell::new(v.clone());
        assert_eq!(cell.load().seq, 1);
        v.seq = 2;
        cell.publish(v);
        assert_eq!(cell.load().seq, 2);
    }

    #[test]
    fn intern_is_dense_first_appearance() {
        let mut gate = FrontendGate::new(Admission::default(), 2);
        assert_eq!(gate.intern(4, 1), StreamId(0));
        assert_eq!(gate.intern(2, 0), StreamId(1));
        assert_eq!(gate.intern(4, 1), StreamId(0));
    }

    #[test]
    fn epoch_retires_idle_drained_streams_only() {
        let mut gate = FrontendGate::new(Admission::new(64), 1);
        let a = gate.intern(0, 0);
        let b = gate.intern(1, 0);
        assert_eq!(gate.decide(&view(gview(0, 0)), 0, &req(a.0, 1e9), 0.0), Admit::Accept);
        assert_eq!(gate.decide(&view(gview(0, 0)), 0, &req(b.0, 1e9), 0.0), Admit::Accept);
        // a's accept was drained; b's is still in the channel
        let mut v = view(gview(0, 0));
        v.drained = vec![1];
        v.drained_by_stream = BTreeMap::from([(a.0, 1)]);
        // first boundary: both streams were active this epoch — no retire
        assert!(gate.advance_epoch(&v).is_empty(), "active streams survive");
        // second boundary: both idle, but only a is fully drained
        let retired = gate.advance_epoch(&v);
        assert_eq!(retired, vec![a.0], "in-channel work blocks retirement");
        assert_eq!(gate.tracked_streams(), 1);
        // a returns: interned as a FRESH id — never a reused one
        let a2 = gate.intern(0, 0);
        assert_ne!(a2, a, "retired ids are never reused");
        assert_eq!(a2, StreamId(2));
        assert_eq!(gate.tracked_streams(), 2);
    }

    #[test]
    fn frontend_bookkeeping_bounded_under_tenant_churn() {
        // the gate-side mirror of the window's churn regression
        // (`bookkeeping_bounded_under_tenant_churn`): N tenants each
        // accept and drain a request, then go idle; after each tenant's
        // epoch pair the gate must be back to a handful of live streams,
        // not N — and the scheduler's mirrored drain counters (compacted
        // via the returned Retire ids) stay bounded too
        let mut gate = FrontendGate::new(Admission::new(64), 1);
        let mut drained_total = 0u64;
        let mut sched_drained: BTreeMap<u32, u64> = BTreeMap::new();
        for t in 0..200u32 {
            let s = gate.intern(t, 0);
            let mut v = view(gview(0, 0));
            v.drained = vec![drained_total];
            v.drained_by_stream = sched_drained.clone();
            assert_eq!(gate.decide(&v, 0, &req(s.0, 1e9), 0.0), Admit::Accept);
            // the scheduler drains the accept and publishes
            drained_total += 1;
            sched_drained.insert(s.0, 1);
            let mut v2 = view(gview(0, 0));
            v2.drained = vec![drained_total];
            v2.drained_by_stream = sched_drained.clone();
            // one epoch of activity, one epoch of idleness → retired
            gate.advance_epoch(&v2);
            for id in gate.advance_epoch(&v2) {
                sched_drained.remove(&id);
            }
            assert!(
                gate.tracked_streams() <= 1,
                "gate leaks streams after tenant {t}: {}",
                gate.tracked_streams()
            );
            assert!(
                sched_drained.len() <= 1,
                "scheduler drain mirror leaks after tenant {t}: {}",
                sched_drained.len()
            );
        }
    }

    #[test]
    fn best_effort_capped_below_latency_classes_at_the_gate() {
        // one pricing path, per-class decisions: with the queue at the BE
        // share, a best-effort request sheds while a standard one passes
        let mut gate = FrontendGate::new(Admission::new(8), 1); // BE cap 4
        let v = view(gview(4, 0));
        let s = gate.intern(0, 0);
        let be = GateRequest {
            class: SloClass::BestEffort,
            ..req(s.0, 1e9)
        };
        assert_eq!(gate.decide(&v, 0, &be, 0.0), Admit::Reject);
        assert_eq!(gate.decide(&v, 0, &req(s.0, 1e9), 0.0), Admit::Accept);
        let crit = GateRequest {
            class: SloClass::Critical,
            ..req(s.0, 1e9)
        };
        assert_eq!(gate.decide(&v, 0, &crit, 0.0), Admit::Accept);
    }

    #[test]
    fn decide_reason_matches_decide_and_names_the_shed() {
        // unknown group → queue-full taxonomy
        let v = view(gview(0, 0));
        let mut gate = FrontendGate::new(Admission::default(), 1);
        assert_eq!(
            gate.decide_reason(&v, 9, &req(0, 1e9), 0.0),
            Some(RejectReason::QueueFull)
        );
        // priced out by the bounded queue → queue-full
        let mut gate = FrontendGate::new(Admission::new(1), 1);
        let s = gate.intern(0, 0);
        assert_eq!(gate.decide_reason(&v, 0, &req(s.0, 1e9), 0.0), None);
        assert_eq!(
            gate.decide_reason(&v, 0, &req(s.0, 1e9), 0.0),
            Some(RejectReason::QueueFull)
        );
        // best-effort on a stale view → stale-shed, standard unaffected
        let mut gate = FrontendGate::new(Admission::new(64), 1);
        let s = gate.intern(0, 0);
        let mut stale = view(gview(0, 0));
        stale.published = Instant::now()
            - std::time::Duration::from_micros(2 * STALE_VIEW_US as u64);
        let be = GateRequest {
            class: SloClass::BestEffort,
            ..req(s.0, 1e9)
        };
        assert_eq!(
            gate.decide_reason(&stale, 0, &be, 0.0),
            Some(RejectReason::StaleShed)
        );
        assert_eq!(gate.decide_reason(&stale, 0, &req(s.0, 1e9), 0.0), None);
        // the wrapper agrees with the taxonomy
        assert_eq!(gate.decide(&stale, 0, &be, 0.0), Admit::Reject);
    }

    #[test]
    fn token_bucket_shapes_a_saturating_tenant() {
        // 2 req/s with burst 2: the burst is admitted, the third request
        // at t=0 is shaped; half a second later one token has refilled
        let mut shaper = TenantShaper::default();
        shaper.set_limit(7, 2.0, 2.0);
        assert!(shaper.admit(7, 0.0));
        assert!(shaper.admit(7, 0.0));
        assert!(!shaper.admit(7, 0.0), "burst exhausted");
        assert!(shaper.admit(7, 500_000.0), "refilled at rate");
        assert!(!shaper.admit(7, 500_000.0));
        // unshaped tenants always pass
        for _ in 0..100 {
            assert!(shaper.admit(8, 0.0));
        }
    }

    #[test]
    fn token_bucket_never_credits_backwards_time() {
        let mut b = TokenBucket::new(1.0, 1.0);
        assert!(b.try_take(1_000_000.0));
        // an out-of-order earlier timestamp must not refill the bucket
        assert!(!b.try_take(0.0));
        assert!(!b.try_take(1_500_000.0), "half a token only");
        assert!(b.try_take(2_000_000.0));
    }

    #[test]
    fn retired_stream_counters_restart_clean() {
        // after retirement, a returning pair's fresh id starts with a
        // zero accept counter — a stale drained entry for the OLD id must
        // not bleed into the new stream's in-channel arithmetic
        let mut gate = FrontendGate::new(Admission::new(2), 1);
        let s = gate.intern(0, 0);
        assert_eq!(gate.decide(&view(gview(0, 0)), 0, &req(s.0, 1e9), 0.0), Admit::Accept);
        let mut v = view(gview(0, 0));
        v.drained = vec![1];
        v.drained_by_stream = BTreeMap::from([(s.0, 1)]);
        gate.advance_epoch(&v);
        assert_eq!(gate.advance_epoch(&v), vec![s.0]);
        let s2 = gate.intern(0, 0);
        // the view still carries the old id's drain count (the engine
        // compacts asynchronously) — irrelevant to the fresh id
        assert_eq!(
            gate.decide(&v, 0, &req(s2.0, 1e9), 0.0),
            Admit::Accept,
            "fresh stream prices from zero"
        );
    }
}
