//! Per-connection outbound reply queues and the single writer stage.
//!
//! The reply router used to write frames directly into a mutex-guarded
//! clone of each connection's socket, retrying `WouldBlock` in place —
//! so one client that stopped reading could park the router (and every
//! other connection's replies) behind its full send buffer. This module
//! breaks that coupling: producers (the reply router resolving batches,
//! the shards naming protocol errors) only *enqueue* fully framed bytes
//! onto the target connection's FIFO and return immediately; the
//! `vliw-writer` stage sweeps the queues with non-blocking writes and a
//! per-socket exponential backoff, so a stalled socket costs exactly its
//! own queue and nothing else.
//!
//! Bounded by construction: a connection may hold at most
//! [`CONN_QUEUE_CAP`] frames — overflowing marks it dead (a client that
//! is 4096 replies behind is not coming back) and its frames drop, with
//! every dropped reply counted. Shutdown drains best-effort for a short
//! grace period, then counts whatever is still queued as dropped.

use std::collections::{HashMap, VecDeque};
use std::io::{self, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::serve::intake::wire::{write_frame, FrameKind};
use crate::util::threadpool::Notify;

/// Hard cap on frames queued per connection; overflow kills the
/// connection's queue rather than growing without bound.
pub(crate) const CONN_QUEUE_CAP: usize = 4096;

/// First backoff after a `WouldBlock`; doubles per consecutive strike.
const BACKOFF_BASE: Duration = Duration::from_micros(200);
/// Ceiling of the per-socket exponential backoff.
const BACKOFF_MAX: Duration = Duration::from_millis(5);
/// How long the writer keeps draining queued frames after stop.
const SHUTDOWN_GRACE: Duration = Duration::from_millis(250);
/// Idle poll interval when no queue has work and no backoff is armed.
const IDLE_WAIT: Duration = Duration::from_micros(500);

/// One connection's write half and its pending frames.
struct ConnOut {
    stream: TcpStream,
    /// Fully framed messages, FIFO; the flag marks reply frames (the
    /// only kind the drop accounting tracks).
    queue: VecDeque<(Vec<u8>, bool)>,
    /// Bytes of `queue.front()` already on the wire (partial write).
    sent: usize,
    /// The sweep skips this socket until then (armed by `WouldBlock`).
    backoff_until: Option<Instant>,
    /// Consecutive `WouldBlock` strikes, drives the backoff doubling.
    strikes: u32,
    /// Write error or queue overflow: frames drop, entry is removed.
    dead: bool,
    /// Connection closed by its shard: remove once the queue drains.
    retired: bool,
}

#[derive(Default)]
struct OutboundState {
    conns: HashMap<u64, ConnOut>,
    /// Reply frames fully written to their socket.
    replies_written: u64,
    /// Reply frames dropped: unknown/dead connection at enqueue, queue
    /// overflow, write error, or still queued when shutdown gave up.
    replies_dropped: u64,
}

/// The shared outbound table: producers enqueue, the writer stage
/// drains. See the module docs for the isolation contract.
#[derive(Default)]
pub(crate) struct Outbound {
    state: Mutex<OutboundState>,
    notify: Notify,
    stop: AtomicBool,
}

impl Outbound {
    fn lock(&self) -> std::sync::MutexGuard<'_, OutboundState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Adopt a connection's write half (called by its shard before any
    /// frame for it can be produced).
    pub(crate) fn register(&self, conn: u64, stream: TcpStream) {
        self.lock().conns.insert(
            conn,
            ConnOut {
                stream,
                queue: VecDeque::new(),
                sent: 0,
                backoff_until: None,
                strikes: 0,
                dead: false,
                retired: false,
            },
        );
    }

    /// Mark a connection closed: the writer removes it once its queue
    /// drains (the shard's parting error frame still gets its chance).
    pub(crate) fn retire(&self, conn: u64) {
        let mut s = self.lock();
        if let Some(c) = s.conns.get_mut(&conn) {
            c.retired = true;
        }
        drop(s);
        self.notify.notify();
    }

    /// Queue one frame for a connection. Returns whether the frame was
    /// accepted; a rejected reply frame is counted as dropped.
    pub(crate) fn enqueue(&self, conn: u64, kind: FrameKind, payload: &[u8]) -> bool {
        let mut frame = Vec::with_capacity(payload.len() + 8);
        if write_frame(&mut frame, kind, payload).is_err() {
            // oversized payload; replies never get here
            return false;
        }
        let is_reply = kind == FrameKind::Reply;
        let mut s = self.lock();
        let mut dropped_now = 0u64;
        let accepted = match s.conns.get_mut(&conn) {
            None => {
                dropped_now += is_reply as u64;
                false
            }
            Some(c) if c.dead => {
                dropped_now += is_reply as u64;
                false
            }
            Some(c) if c.queue.len() >= CONN_QUEUE_CAP => {
                // thousands of unread frames: the peer is not consuming.
                // Kill the queue instead of growing it without bound.
                c.dead = true;
                dropped_now += is_reply as u64;
                for (_, r) in c.queue.drain(..) {
                    dropped_now += r as u64;
                }
                false
            }
            Some(c) => {
                c.queue.push_back((frame, is_reply));
                true
            }
        };
        s.replies_dropped += dropped_now;
        drop(s);
        if accepted {
            self.notify.notify();
        }
        accepted
    }

    /// `(written, dropped)` reply-frame totals. Final only after the
    /// writer stage has joined.
    pub(crate) fn stats(&self) -> (u64, u64) {
        let s = self.lock();
        (s.replies_written, s.replies_dropped)
    }

    /// Begin shutdown: the writer drains what it can within the grace
    /// period, counts the rest dropped, and exits.
    pub(crate) fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.notify.notify();
    }

    /// One pass over every connection: write as much as each socket
    /// takes without blocking. Returns whether any bytes moved and the
    /// nearest armed backoff deadline.
    fn sweep(&self) -> (bool, Option<Duration>) {
        let now = Instant::now();
        let mut s = self.lock();
        let mut progressed = false;
        let mut next_backoff: Option<Duration> = None;
        let mut written_now = 0u64;
        let mut dropped_now = 0u64;
        let mut remove: Vec<u64> = Vec::new();
        for (&id, c) in s.conns.iter_mut() {
            if c.queue.is_empty() {
                if c.retired || c.dead {
                    remove.push(id);
                }
                continue;
            }
            if let Some(t) = c.backoff_until {
                if t > now {
                    let wait = t - now;
                    next_backoff = Some(next_backoff.map_or(wait, |n| n.min(wait)));
                    continue;
                }
            }
            loop {
                let Some(front) = c.queue.front() else { break };
                let is_reply = front.1;
                let len = front.0.len();
                let res = c.stream.write(&front.0[c.sent..]);
                match res {
                    Ok(0) => {
                        c.dead = true;
                        break;
                    }
                    Ok(n) => {
                        progressed = true;
                        c.sent += n;
                        c.strikes = 0;
                        c.backoff_until = None;
                        if c.sent == len {
                            written_now += is_reply as u64;
                            c.queue.pop_front();
                            c.sent = 0;
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        c.strikes = c.strikes.saturating_add(1);
                        let backoff = BACKOFF_BASE
                            .saturating_mul(1u32 << c.strikes.min(5))
                            .min(BACKOFF_MAX);
                        c.backoff_until = Some(now + backoff);
                        next_backoff =
                            Some(next_backoff.map_or(backoff, |n| n.min(backoff)));
                        break;
                    }
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                    Err(_) => {
                        c.dead = true;
                        break;
                    }
                }
            }
            if c.dead {
                for (_, r) in c.queue.drain(..) {
                    dropped_now += r as u64;
                }
                remove.push(id);
            } else if c.queue.is_empty() && c.retired {
                remove.push(id);
            }
        }
        for id in remove {
            s.conns.remove(&id);
        }
        s.replies_written += written_now;
        s.replies_dropped += dropped_now;
        (progressed, next_backoff)
    }

    /// The `vliw-writer` stage body: sweep, sleep on the eventcount (or
    /// until the nearest backoff expires), repeat. After `stop`, drain
    /// within the grace period, then count the leftovers dropped.
    pub(crate) fn writer_loop(&self) {
        let mut stop_at: Option<Instant> = None;
        loop {
            let epoch = self.notify.epoch();
            let (progressed, next_backoff) = self.sweep();
            if self.stop.load(Ordering::SeqCst) {
                let deadline =
                    *stop_at.get_or_insert_with(|| Instant::now() + SHUTDOWN_GRACE);
                let drained = self.lock().conns.values().all(|c| c.queue.is_empty());
                if drained || Instant::now() >= deadline {
                    break;
                }
            }
            if !progressed {
                self.notify
                    .wait_past(epoch, next_backoff.unwrap_or(IDLE_WAIT));
            }
        }
        // whatever is still queued has no writer anymore
        let mut s = self.lock();
        let mut dropped_now = 0u64;
        for c in s.conns.values_mut() {
            for (_, r) in c.queue.drain(..) {
                dropped_now += r as u64;
            }
        }
        s.replies_dropped += dropped_now;
    }
}
