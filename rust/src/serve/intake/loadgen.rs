//! The wire load generator: replays a timed wire workload against a
//! serve endpoint over real TCP connections and reports CLIENT-observed
//! latency — the number the server's own histograms structurally cannot
//! contain (it includes framing, kernel socket queues, and the reply
//! path). Shared by `vliwd loadgen`, `vliwd bench --wire`, and the
//! loopback e2e tests.
//!
//! Streams stick to connections (`tenant % conns`), so a dependent
//! stream's requests ride one socket in program order — the server side
//! guarantees per-connection order through its shards, which makes the
//! pair an end-to-end ordering contract.

use std::collections::HashMap;
use std::io;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::serve::intake::wire::{
    decode_reply, encode_request, read_frame, write_frame, FrameKind, WireOpStatus,
};
use crate::util::stats::LatencyHist;
use crate::util::threadpool::Stage;
use crate::workload::wire::TimedWireRequest;

/// How long a reader waits on a quiet socket before giving up on
/// outstanding replies.
const REPLY_TIMEOUT: Duration = Duration::from_secs(10);

/// What the load generator observed, aggregated over all connections.
#[derive(Default)]
pub struct LoadgenReport {
    /// Request frames written.
    pub sent_batches: u64,
    /// Ops inside those frames.
    pub sent_ops: u64,
    /// Reply frames received.
    pub replies: u64,
    /// Per-op statuses inside the replies.
    pub ok_ops: u64,
    pub rejected_ops: u64,
    pub failed_ops: u64,
    /// Ops that completed within their deadline (server-judged).
    pub met_ops: u64,
    /// Client-observed per-BATCH latency: frame write → reply read, µs.
    pub latency: LatencyHist,
    /// Connections that gave up waiting for outstanding replies.
    pub timeouts: u64,
}

impl LoadgenReport {
    /// Client-side attainment: ops confirmed on-deadline over ops sent.
    /// Unanswered ops count against it — from the client's chair a lost
    /// reply and a miss are the same thing.
    pub fn attainment(&self) -> f64 {
        if self.sent_ops == 0 {
            1.0
        } else {
            self.met_ops as f64 / self.sent_ops as f64
        }
    }

    fn merge(&mut self, o: &LoadgenReport) {
        self.sent_batches += o.sent_batches;
        self.sent_ops += o.sent_ops;
        self.replies += o.replies;
        self.ok_ops += o.ok_ops;
        self.rejected_ops += o.rejected_ops;
        self.failed_ops += o.failed_ops;
        self.met_ops += o.met_ops;
        self.latency.merge(&o.latency);
        self.timeouts += o.timeouts;
    }
}

/// One connection's paced send schedule.
struct ConnWork {
    /// (send at µs from run start, client id, encoded payload, op count)
    items: Vec<(f64, u64, Vec<u8>, u64)>,
}

/// Replay `reqs` (already timed and sorted — see
/// [`crate::workload::wire::trace_to_wire`]) over `conns` connections.
/// Each connection runs a paced writer thread and a reader thread;
/// returns when every connection has its replies or timed out.
pub fn run_loadgen(
    addr: SocketAddr,
    reqs: &[TimedWireRequest],
    conns: usize,
) -> io::Result<LoadgenReport> {
    let conns = conns.max(1);
    let mut per_conn: Vec<ConnWork> = (0..conns).map(|_| ConnWork { items: vec![] }).collect();
    for r in reqs {
        per_conn[(r.tenant as usize) % conns].items.push((
            r.at_us,
            r.req.id,
            encode_request(&r.req),
            r.req.ops.len() as u64,
        ));
    }
    let t0 = Instant::now();
    let mut writers: Vec<Stage<LoadgenReport>> = Vec::new();
    let mut readers: Vec<Stage<LoadgenReport>> = Vec::new();
    for (c, work) in per_conn.into_iter().enumerate() {
        if work.items.is_empty() {
            continue;
        }
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let read_half = stream.try_clone()?;
        let sent_times: Arc<Mutex<HashMap<u64, Instant>>> = Arc::default();
        let sent = Arc::new(AtomicU64::new(0));
        let done = Arc::new(AtomicBool::new(false));

        let w_times = Arc::clone(&sent_times);
        let w_sent = Arc::clone(&sent);
        let w_done = Arc::clone(&done);
        writers.push(Stage::spawn(&format!("loadgen-w{c}"), move || {
            let mut stream = stream;
            let mut rep = LoadgenReport::default();
            for (at_us, id, payload, n_ops) in work.items {
                let target = Duration::from_micros(at_us as u64);
                let elapsed = t0.elapsed();
                if target > elapsed {
                    std::thread::sleep(target - elapsed);
                }
                // stamp BEFORE the write so the reply can never race
                // the bookkeeping
                w_times
                    .lock()
                    .unwrap_or_else(|p| p.into_inner())
                    .insert(id, Instant::now());
                if write_frame(&mut stream, FrameKind::Request, &payload).is_err() {
                    w_times.lock().unwrap_or_else(|p| p.into_inner()).remove(&id);
                    break;
                }
                rep.sent_batches += 1;
                rep.sent_ops += n_ops;
                w_sent.fetch_add(1, Ordering::SeqCst);
            }
            w_done.store(true, Ordering::SeqCst);
            rep
        }));

        readers.push(Stage::spawn(&format!("loadgen-r{c}"), move || {
            let mut stream = read_half;
            let _ = stream.set_read_timeout(Some(REPLY_TIMEOUT));
            let mut rep = LoadgenReport::default();
            loop {
                if done.load(Ordering::SeqCst) && rep.replies >= sent.load(Ordering::SeqCst)
                {
                    break;
                }
                match read_frame(&mut stream) {
                    Ok(f) if f.kind == FrameKind::Reply => {
                        let Ok(reply) = decode_reply(&f.payload) else {
                            break;
                        };
                        rep.replies += 1;
                        if let Some(t) = sent_times
                            .lock()
                            .unwrap_or_else(|p| p.into_inner())
                            .remove(&reply.id)
                        {
                            rep.latency.record_us(t.elapsed().as_secs_f64() * 1e6);
                        }
                        for op in reply.ops {
                            match op {
                                WireOpStatus::Ok { met_deadline, .. } => {
                                    rep.ok_ops += 1;
                                    if met_deadline {
                                        rep.met_ops += 1;
                                    }
                                }
                                WireOpStatus::Rejected { .. } => rep.rejected_ops += 1,
                                WireOpStatus::Failed => rep.failed_ops += 1,
                            }
                        }
                    }
                    Ok(_) => break, // error frame: the server is hanging up
                    Err(e)
                        if e.kind() == io::ErrorKind::WouldBlock
                            || e.kind() == io::ErrorKind::TimedOut =>
                    {
                        rep.timeouts += 1;
                        break;
                    }
                    Err(_) => break,
                }
            }
            rep
        }));
    }
    let mut total = LoadgenReport::default();
    for w in writers {
        total.merge(&w.join());
    }
    for r in readers {
        total.merge(&r.join());
    }
    Ok(total)
}
