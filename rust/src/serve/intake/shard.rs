//! Intake shard workers: each owns a set of connections' read halves
//! and pumps them non-blocking — decode, validate, register the batch,
//! forward its ops to the engine. See the [`crate::serve::intake`]
//! module docs for the threading model and ordering contract.

use std::collections::BTreeMap;
use std::io::{self, Read};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::runtime::golden;
use crate::serve::engine::Incoming;
use crate::serve::intake::outbound::Outbound;
use crate::serve::intake::wire::{
    encode_error, FrameBuf, FrameKind, WireOpStatus, MAX_BATCH_OPS,
};
use crate::serve::intake::ReplyTable;
use crate::util::stats::LatencyHist;
use crate::util::threadpool::Notify;

/// Everything one shard worker needs, bundled for the spawn.
pub(crate) struct ShardCtx {
    /// New connections handed over by the acceptor.
    pub conn_rx: mpsc::Receiver<(u64, TcpStream)>,
    /// The engine's intake channel (per-sender FIFO: one shard's
    /// forwards arrive in order).
    pub engine_tx: mpsc::Sender<Incoming>,
    pub table: Arc<ReplyTable>,
    /// Per-connection outbound frame queues (replies + error frames);
    /// the shard enqueues, the writer stage owns the sockets' write
    /// halves.
    pub outbound: Arc<Outbound>,
    /// model name → (group id, d_in), in the engine's sorted-name order.
    pub slot_map: BTreeMap<String, (u64, usize)>,
    pub stop: Arc<AtomicBool>,
    pub notify: Arc<Notify>,
    /// Shared batch-id allocator (starts at 1; token 0 is reserved).
    pub batch_ids: Arc<AtomicU64>,
}

/// One shard's thread-local accounting, folded into
/// [`crate::serve::metrics::IntakeMetrics`] at shutdown.
#[derive(Default)]
pub(crate) struct IntakeShardReport {
    /// Frame decode time (bytes → validated request), µs.
    pub decode: LatencyHist,
    /// Frame read → last op forwarded to the engine, µs.
    pub accept_latency: LatencyHist,
    /// Client batch size → request count.
    pub batch_sizes: BTreeMap<u32, u64>,
    /// Ops forwarded to the engine.
    pub forwarded: u64,
    /// Connections adopted.
    pub connections: u64,
    /// Connections that closed or errored.
    pub disconnects: u64,
    /// High-water mark of simultaneously open connections.
    pub peak_conns: u64,
    /// Connections dropped for protocol violations (bad version/kind/
    /// length, malformed payload, oversized batch).
    pub protocol_errors: u64,
}

struct Conn {
    id: u64,
    stream: TcpStream,
    buf: FrameBuf,
}

/// Why a connection left the shard.
enum Close {
    Eof,
    Protocol(String),
}

/// The shard worker body: adopt connections, pump them, forward ops,
/// sleep on the eventcount when idle. Exits on the stop flag; drops its
/// engine sender so the engine can drain.
pub(crate) fn shard_loop(ctx: ShardCtx) -> IntakeShardReport {
    let mut report = IntakeShardReport::default();
    let mut conns: Vec<Conn> = Vec::new();
    loop {
        // epoch BEFORE checking work sources: a pulse that lands while
        // we pump is never lost across the idle wait below
        let epoch = ctx.notify.epoch();
        while let Ok((id, stream)) = ctx.conn_rx.try_recv() {
            match adopt(id, stream, &ctx.outbound) {
                Some(conn) => {
                    report.connections += 1;
                    conns.push(conn);
                }
                None => report.disconnects += 1,
            }
        }
        report.peak_conns = report.peak_conns.max(conns.len() as u64);
        let mut progressed = false;
        let mut closing: Vec<(usize, Close)> = Vec::new();
        for (i, conn) in conns.iter_mut().enumerate() {
            match pump(conn, &ctx, &mut report) {
                Ok(moved) => progressed |= moved,
                Err(close) => closing.push((i, close)),
            }
        }
        for (i, close) in closing.into_iter().rev() {
            let conn = conns.swap_remove(i);
            if let Close::Protocol(msg) = close {
                report.protocol_errors += 1;
                // best effort: name the violation before hanging up
                ctx.outbound
                    .enqueue(conn.id, FrameKind::Error, &encode_error(&msg));
            }
            ctx.table.drop_conn(conn.id);
            // the writer drops the queue entry once the parting frames
            // drain (or its socket errors)
            ctx.outbound.retire(conn.id);
            report.disconnects += 1;
            progressed = true;
        }
        if ctx.stop.load(Ordering::SeqCst) {
            break;
        }
        if !progressed {
            ctx.notify.wait_past(epoch, Duration::from_micros(500));
        }
    }
    // shutdown: every live connection's pending batches are purged so
    // the reply table never outlives its sockets
    for conn in conns.drain(..) {
        ctx.table.drop_conn(conn.id);
        ctx.outbound.retire(conn.id);
        report.disconnects += 1;
    }
    report
}

/// Switch an adopted connection to non-blocking and hand its write half
/// to the outbound writer. `None` = the socket died during handover.
fn adopt(id: u64, stream: TcpStream, outbound: &Outbound) -> Option<Conn> {
    stream.set_nonblocking(true).ok()?;
    stream.set_nodelay(true).ok();
    outbound.register(id, stream.try_clone().ok()?);
    Some(Conn {
        id,
        stream,
        buf: FrameBuf::new(),
    })
}

/// Pump one connection: drain the socket into its frame buffer, then
/// handle every complete frame. Returns whether anything moved; `Err`
/// closes the connection.
fn pump(
    conn: &mut Conn,
    ctx: &ShardCtx,
    report: &mut IntakeShardReport,
) -> Result<bool, Close> {
    let mut moved = false;
    let mut tmp = [0u8; 4096];
    loop {
        match conn.stream.read(&mut tmp) {
            Ok(0) => {
                // EOF: the peer closed. Frames already buffered are
                // worthless — their replies have no reader.
                return Err(Close::Eof);
            }
            Ok(n) => {
                conn.buf.extend(&tmp[..n]);
                moved = true;
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => return Err(Close::Eof),
        }
    }
    loop {
        match conn.buf.next_frame() {
            Ok(Some(frame)) => {
                if frame.kind != FrameKind::Request {
                    return Err(Close::Protocol("only request frames accepted".into()));
                }
                handle_request(conn, &frame.payload, ctx, report)?;
                moved = true;
            }
            Ok(None) => break,
            Err(e) => return Err(Close::Protocol(e.to_string())),
        }
    }
    Ok(moved)
}

/// Decode, validate, register, forward one request frame.
fn handle_request(
    conn: &Conn,
    payload: &[u8],
    ctx: &ShardCtx,
    report: &mut IntakeShardReport,
) -> Result<(), Close> {
    let t_read = Instant::now();
    let req = crate::serve::intake::wire::decode_request(payload)
        .map_err(|e| Close::Protocol(e.to_string()))?;
    report
        .decode
        .record_us(t_read.elapsed().as_secs_f64() * 1e6);
    if req.ops.is_empty() {
        return Err(Close::Protocol("empty batch".into()));
    }
    if req.ops.len() > MAX_BATCH_OPS {
        return Err(Close::Protocol(format!(
            "batch of {} over the {MAX_BATCH_OPS} cap",
            req.ops.len()
        )));
    }
    let batch = ctx.batch_ids.fetch_add(1, Ordering::Relaxed);
    let n = req.ops.len();
    // register FIRST: once ops are forwarded, completions may resolve
    // on the router thread immediately
    ctx.table.register(conn.id, batch, req.id, n);
    for (i, op) in req.ops.into_iter().enumerate() {
        let token = (batch << 16) | i as u64;
        let Some(&(group, d_in)) = ctx.slot_map.get(&op.model) else {
            // an unknown model is a per-op reject, not a connection
            // error — the partial-accept contract answers it in place
            ctx.table.resolve(
                token,
                WireOpStatus::Rejected {
                    reason: "unknown_model".to_string(),
                },
            );
            continue;
        };
        let inc = Incoming {
            tenant: op.tenant,
            group,
            slo_us: op.slo_us,
            class: op.class,
            arrival: Instant::now(),
            row: golden::gen_hash01(d_in, op.seed),
            token,
        };
        if ctx.engine_tx.send(inc).is_err() {
            // engine gone (shutdown race): terminal-fail the op so the
            // batch still answers
            ctx.table.resolve(token, WireOpStatus::Failed);
            continue;
        }
        report.forwarded += 1;
    }
    report
        .accept_latency
        .record_us(t_read.elapsed().as_secs_f64() * 1e6);
    *report.batch_sizes.entry(n as u32).or_insert(0) += 1;
    Ok(())
}
