//! Network intake: the real wire in front of the serving engine.
//!
//! `vliwd serve --listen` binds a TCP listener and feeds the ONE serving
//! event loop ([`crate::serve::engine`]) from sockets instead of an
//! in-process trace generator. The design splits into four thread roles
//! and one table; everything else is the existing engine, untouched.
//!
//! # Wire format
//!
//! Every message is one frame: a 6-byte header — `version: u8`,
//! `kind: u8` (0 = request, 1 = reply, 2 = error), `len: u32`
//! little-endian — followed by `len` bytes of JSON payload
//! ([`wire::MAX_FRAME_LEN`] cap). **Version negotiation** is
//! fail-closed: the server speaks exactly [`wire::WIRE_VERSION`]; a
//! frame stamped with any other version is answered with an error frame
//! (which names the server's version) and the connection is closed —
//! the client downgrades and reconnects.
//!
//! A **request** payload is `{"id": u64, "ops": [{tenant, model,
//! slo_us, class, seed}, …]}` — one op or a client-side batch of up to
//! [`wire::MAX_BATCH_OPS`]. Input rows are expanded server-side from
//! `seed` (deterministic hash01 rows, same as every other drive mode):
//! the bench wire carries intent, not tensors. A **reply** payload is
//! `{"id", "ops": [status, …]}`, index-aligned with the request.
//!
//! # Batch and reply semantics
//!
//! Intake decomposes a client batch into N independent engine requests
//! stamped with one shared batch id — *re-coalescing them into
//! superkernels is the JIT's job*, that is the whole point of the
//! paper's OoO window. The batch gets exactly ONE reply, sent when the
//! LAST member reaches a terminal state. The **partial-accept
//! contract**: members succeed or die individually, and the reply
//! carries a per-op status — `ok` (with server-side latency and
//! deadline attainment), `rejected` (with the
//! [`crate::serve::frontend::RejectReason`] name:
//! `queue_full`, `rate_limited`, `stale_shed`, or `unknown_model`), or
//! `failed`. A batch with some ops rejected at the gate and others
//! completed is normal, not an error.
//!
//! # Threading model
//!
//! * **Acceptor** (`vliw-accept`, one thread) owns the listener. Each
//!   accepted connection is handed to shard `conn_id % shards` and a
//!   [`Notify`] pulse wakes the shard — so post-idle accept latency is
//!   not floored by the shards' poll interval.
//! * **Shard workers** (`vliw-intake-N`) own their connections' *read*
//!   halves (non-blocking; a [`wire::FrameBuf`] per connection keeps
//!   frame alignment across split reads). A connection lives on ONE
//!   shard for its whole life, and a shard decodes and forwards frames
//!   in arrival order over one mpsc sender — so per-stream program
//!   order is preserved end to end for clients that keep a stream on
//!   one connection. Shards register each batch in the [`ReplyTable`]
//!   *before* forwarding its ops (no completion can race the
//!   registration) and time decode + accept-to-forward latency into
//!   [`crate::serve::metrics::IntakeMetrics`].
//! * **Engine** (`vliw-engine`) runs `Server::run_wire`: the standard
//!   wall-clock loop fed by the shards' channel, with every terminal
//!   op outcome routed out through the engine's reply sink.
//! * **Reply router** (`vliw-reply`, one thread) drains the sink,
//!   resolves tokens against the [`ReplyTable`], and — when a batch's
//!   last member lands — *enqueues* the single reply frame on the
//!   connection's outbound queue and moves on. The router never touches
//!   a socket, so a stalled client cannot park it.
//! * **Reply writer** (`vliw-writer`, one thread) owns every
//!   connection's *write* half through the outbound table: it sweeps
//!   the per-connection frame queues with non-blocking writes and a
//!   per-socket exponential backoff. One client that stops reading
//!   costs exactly its own (capped) queue; every other connection's
//!   replies keep flowing. The shard never writes, the writer never
//!   reads.
//!
//! A client disconnect purges its pending batches from the table
//! (bounded bookkeeping under churn); outcome events for already-purged
//! batches count as `orphan_events` and are dropped.

pub mod loadgen;
mod outbound;
pub mod shard;
pub mod wire;

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use crate::analysis::audit::AuditLog;
use crate::serve::engine::{Incoming, OpEvent, OpOutcome};
use crate::serve::metrics::IntakeShardMetrics;
use crate::serve::server::{ModelBackend, Server, ServeReport};
use crate::util::threadpool::{Notify, Stage};
use crate::workload::trace::TenantSpec;

use outbound::Outbound;
use shard::IntakeShardReport;
use wire::{encode_reply, FrameKind, WireOpStatus, WireReply};

/// One batch awaiting its last member.
struct PendingBatch {
    conn: u64,
    client_id: u64,
    remaining: usize,
    ops: Vec<Option<WireOpStatus>>,
}

#[derive(Default)]
struct ReplyState {
    /// batch id → pending batch.
    pending: HashMap<u64, PendingBatch>,
    orphan_events: u64,
}

/// Tracks per-batch completion across threads: shards register, the
/// reply router resolves, disconnects purge. Tokens pack
/// `(batch id << 16) | op index`; token 0 is reserved for non-wire
/// requests and never reaches this table. Finished replies leave
/// through the connection's [`Outbound`] queue — resolving never
/// touches a socket.
pub struct ReplyTable {
    state: Mutex<ReplyState>,
    outbound: Arc<Outbound>,
    /// Launch-log auditor, if attached: disconnect purges land as
    /// `purge` events so `vliwd audit` can tell a churned connection's
    /// never-replied completions from a genuine lost reply.
    audit: Option<Arc<AuditLog>>,
}

impl ReplyTable {
    /// A table whose replies drain through `outbound` and whose
    /// disconnect purges mirror into `log`.
    fn new(outbound: Arc<Outbound>, log: Option<Arc<AuditLog>>) -> Self {
        ReplyTable {
            state: Mutex::default(),
            outbound,
            audit: log,
        }
    }

    /// Register a batch BEFORE its ops are forwarded to the engine, so
    /// no completion can arrive for an unregistered batch.
    fn register(&self, conn: u64, batch: u64, client_id: u64, n: usize) {
        let mut s = self.state.lock().unwrap_or_else(|p| p.into_inner());
        s.pending.insert(
            batch,
            PendingBatch {
                conn,
                client_id,
                remaining: n,
                ops: vec![None; n],
            },
        );
    }

    /// Record one op's terminal status; when it is the batch's last,
    /// enqueue the single reply frame and retire the batch.
    fn resolve(&self, token: u64, status: WireOpStatus) {
        let batch = token >> 16;
        let idx = (token & 0xffff) as usize;
        // complete-batch extraction happens under the lock; the frame
        // enqueue happens OUTSIDE it (and is itself non-blocking), so
        // nothing here can ever stall the shards' registrations
        let done = {
            let mut s = self.state.lock().unwrap_or_else(|p| p.into_inner());
            if !s.pending.contains_key(&batch) {
                // the client disconnected and the batch was purged —
                // the engine's late outcome has nowhere to land
                s.orphan_events += 1;
                return;
            }
            let b = s.pending.get_mut(&batch).expect("checked above");
            if idx < b.ops.len() && b.ops[idx].is_none() {
                b.ops[idx] = Some(status);
                b.remaining -= 1;
            }
            if b.remaining > 0 {
                return;
            }
            s.pending.remove(&batch).expect("batch present")
        };
        let reply = WireReply {
            id: done.client_id,
            ops: done
                .ops
                .into_iter()
                .map(|st| st.unwrap_or(WireOpStatus::Failed))
                .collect(),
        };
        // accepted-or-dropped accounting lives in the outbound table
        self.outbound
            .enqueue(done.conn, FrameKind::Reply, &encode_reply(&reply));
    }

    /// Purge every pending batch of a closed connection — nothing will
    /// read its replies, and the bookkeeping must not outlive it.
    fn drop_conn(&self, conn: u64) {
        let mut s = self.state.lock().unwrap_or_else(|p| p.into_inner());
        let purged: Vec<u64> = s
            .pending
            .iter()
            .filter(|(_, b)| b.conn == conn)
            .map(|(&id, _)| id)
            .collect();
        s.pending.retain(|_, b| b.conn != conn);
        drop(s);
        if !purged.is_empty() {
            if let Some(log) = &self.audit {
                log.purge(conn, &purged);
            }
        }
    }

    /// Batches still awaiting members (test hook: leak detection).
    pub fn pending_batches(&self) -> usize {
        let s = self.state.lock().unwrap_or_else(|p| p.into_inner());
        s.pending.len()
    }

    fn orphan_events(&self) -> u64 {
        let s = self.state.lock().unwrap_or_else(|p| p.into_inner());
        s.orphan_events
    }
}

/// Map an engine outcome to the wire status taxonomy.
fn status_of(outcome: OpOutcome) -> WireOpStatus {
    match outcome {
        OpOutcome::Done {
            latency_us,
            met_deadline,
        } => WireOpStatus::Ok {
            latency_us,
            met_deadline,
        },
        OpOutcome::Failed => WireOpStatus::Failed,
        OpOutcome::Rejected(r) => WireOpStatus::Rejected {
            reason: r.name().to_string(),
        },
    }
}

/// A running wire server: the listener is bound, the intake shards, the
/// engine, and the reply router are live. [`WireServer::shutdown`]
/// tears the pipeline down in dependency order and returns the engine's
/// report with the folded intake metrics.
pub struct WireServer {
    addr: SocketAddr,
    table: Arc<ReplyTable>,
    outbound: Arc<Outbound>,
    stop: Arc<AtomicBool>,
    notify: Arc<Notify>,
    acceptor: Stage<u64>,
    shards: Vec<Stage<IntakeShardReport>>,
    engine: Stage<ServeReport>,
    router: Stage<()>,
    writer: Stage<()>,
}

impl WireServer {
    /// The bound listen address (resolves `:0` to the real port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Batches still awaiting their last member (test hook).
    pub fn pending_batches(&self) -> usize {
        self.table.pending_batches()
    }

    /// Stop accepting, drain the shards, let the engine finish its
    /// in-flight window, and fold intake accounting into the report.
    pub fn shutdown(self) -> ServeReport {
        self.stop.store(true, Ordering::SeqCst);
        self.notify.notify();
        let _accepted = self.acceptor.join();
        let shard_reports: Vec<IntakeShardReport> =
            self.shards.into_iter().map(|s| s.join()).collect();
        // the shards dropped their engine senders: the engine sees the
        // intake disconnect, drains its window, and returns its report
        let mut report = self.engine.join();
        // the engine dropped the reply sink: the router drains and exits
        self.router.join();
        // the router enqueued its last frames — bounded-drain the
        // writer, then its written/dropped counts are final
        self.outbound.stop();
        self.writer.join();
        let intake = &mut report.metrics.intake;
        for r in &shard_reports {
            intake.decode.merge(&r.decode);
            intake.accept_latency.merge(&r.accept_latency);
            intake.connections += r.connections;
            intake.disconnects += r.disconnects;
            for (&size, &n) in &r.batch_sizes {
                *intake.batch_sizes.entry(size).or_insert(0) += n;
            }
            intake.shards.push(IntakeShardMetrics {
                forwarded: r.forwarded,
                peak_conns: r.peak_conns,
            });
        }
        let (replies, dropped) = self.outbound.stats();
        intake.replies = replies;
        intake.dropped_replies = dropped;
        intake.orphan_events = self.table.orphan_events();
        report
    }
}

/// Bind `listen` and serve a backend over the wire: `make` builds the
/// [`Server`] ON the engine thread (backends need not be `Send`),
/// `tenants` declares the served models and their rate/SLO specs, and
/// `shards` sizes the intake worker pool. `launch_log` mirrors the
/// reply table's disconnect purges into the audit log (the engine's own
/// events are wired through the `Server` the `make` closure builds).
/// Returns once the listener is bound and every stage is live.
pub fn serve_wire<B, F>(
    make: F,
    tenants: Vec<TenantSpec>,
    listen: &str,
    shards: usize,
    launch_log: Option<Arc<AuditLog>>,
) -> io::Result<WireServer>
where
    B: ModelBackend + 'static,
    F: FnOnce() -> Server<B> + Send + 'static,
{
    let shards = shards.max(1);
    let listener = TcpListener::bind(listen)?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;

    // lint: LINT004 shard→engine intake; bounded by per-connection framing
    let (in_tx, in_rx) = mpsc::channel::<Incoming>();
    // lint: LINT004 reply events; at most one per admitted wire op
    let (ev_tx, ev_rx) = mpsc::channel::<OpEvent>();
    // lint: LINT004 startup handshake; exactly one message ever sent
    let (slot_tx, slot_rx) = mpsc::channel::<BTreeMap<String, (u64, usize)>>();

    let engine_tenants = tenants;
    let engine = Stage::spawn("vliw-engine", move || {
        let mut server = make();
        // group id = sorted-name index, the same ordering `model_slots`
        // derives inside `run_wire` — the shards map model names to
        // groups with exactly the table the engine will use
        let names: BTreeSet<String> =
            engine_tenants.iter().map(|t| t.model.clone()).collect();
        let map: BTreeMap<String, (u64, usize)> = names
            .iter()
            .enumerate()
            .map(|(i, n)| (n.clone(), (i as u64, server.backend().d_in(n))))
            .collect();
        let _ = slot_tx.send(map);
        server.run_wire(&engine_tenants, in_rx, ev_tx)
    });
    let slot_map = slot_rx
        .recv()
        .map_err(|_| io::Error::other("engine thread died at startup"))?;

    let outbound = Arc::new(Outbound::default());
    let table = Arc::new(ReplyTable::new(Arc::clone(&outbound), launch_log));
    let stop = Arc::new(AtomicBool::new(false));
    let notify = Arc::new(Notify::new());
    let batch_ids = Arc::new(AtomicU64::new(1));

    let mut conn_txs = Vec::with_capacity(shards);
    let mut shard_stages = Vec::with_capacity(shards);
    for i in 0..shards {
        // lint: LINT004 acceptor→shard handoff; bounded by accept rate
        let (conn_tx, conn_rx) = mpsc::channel::<(u64, TcpStream)>();
        conn_txs.push(conn_tx);
        let ctx = shard::ShardCtx {
            conn_rx,
            engine_tx: in_tx.clone(),
            table: Arc::clone(&table),
            outbound: Arc::clone(&outbound),
            slot_map: slot_map.clone(),
            stop: Arc::clone(&stop),
            notify: Arc::clone(&notify),
            batch_ids: Arc::clone(&batch_ids),
        };
        shard_stages.push(Stage::spawn(&format!("vliw-intake-{i}"), move || {
            shard::shard_loop(ctx)
        }));
    }
    // the shards now hold the only engine senders: when they exit at
    // shutdown the engine sees the disconnect and drains
    drop(in_tx);

    let acc_stop = Arc::clone(&stop);
    let acc_notify = Arc::clone(&notify);
    let acceptor = Stage::spawn("vliw-accept", move || {
        let mut accepted = 0u64;
        while !acc_stop.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let id = accepted;
                    accepted += 1;
                    // connection → shard is a stable assignment for the
                    // connection's lifetime: per-stream order holds as
                    // long as a client keeps a stream on one connection
                    let _ = conn_txs[(id % conn_txs.len() as u64) as usize]
                        .send((id, stream));
                    acc_notify.notify();
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(_) => std::thread::sleep(Duration::from_millis(1)),
            }
        }
        accepted
    });

    let router_table = Arc::clone(&table);
    let router = Stage::spawn("vliw-reply", move || {
        while let Ok(ev) = ev_rx.recv() {
            router_table.resolve(ev.token, status_of(ev.outcome));
        }
    });

    let writer_outbound = Arc::clone(&outbound);
    let writer = Stage::spawn("vliw-writer", move || writer_outbound.writer_loop());

    Ok(WireServer {
        addr,
        table,
        outbound,
        stop,
        notify,
        acceptor,
        shards: shard_stages,
        engine,
        router,
        writer,
    })
}
