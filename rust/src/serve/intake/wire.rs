//! The framed wire protocol: a 6-byte header (`version`, `kind`,
//! little-endian `u32` payload length) followed by a JSON payload. See
//! the [`crate::serve::intake`] module docs for the full frame contract
//! (version negotiation, batch/reply semantics, partial accept).
//!
//! Two decode paths on purpose: [`read_frame`] blocks on an owned socket
//! (the loadgen client's reader thread), while [`FrameBuf`] accumulates
//! whatever bytes a *non-blocking* shard socket produced and yields any
//! complete frames — a shard worker multiplexes many connections and can
//! never park inside one connection's half-read frame.

use std::collections::BTreeMap;
use std::io::{self, ErrorKind, Read, Write};

use crate::compiler::ir::SloClass;
use crate::util::json::{obj, Json};

/// Protocol version this build speaks. A frame with any other version is
/// answered with an [`FrameKind::Error`] frame and the connection closed
/// (closing IS the negotiation: the client learns the server's version
/// from the error payload).
pub const WIRE_VERSION: u8 = 1;

/// Frame header size: version (1) + kind (1) + payload length (4, LE).
pub const HEADER_LEN: usize = 6;

/// Hard payload cap — a length field past this is a protocol error, not
/// an allocation request.
pub const MAX_FRAME_LEN: usize = 1 << 20;

/// Largest client batch one request frame may carry (tokens pack the op
/// index into 16 bits).
pub const MAX_BATCH_OPS: usize = 4096;

/// What a frame carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Client → server: a [`WireRequest`].
    Request,
    /// Server → client: a [`WireReply`].
    Reply,
    /// Server → client: a connection-fatal protocol error (string
    /// payload); the server closes after sending it.
    Error,
}

impl FrameKind {
    fn from_byte(b: u8) -> io::Result<FrameKind> {
        match b {
            0 => Ok(FrameKind::Request),
            1 => Ok(FrameKind::Reply),
            2 => Ok(FrameKind::Error),
            other => Err(bad(format!("unknown frame kind {other}"))),
        }
    }

    fn byte(self) -> u8 {
        match self {
            FrameKind::Request => 0,
            FrameKind::Reply => 1,
            FrameKind::Error => 2,
        }
    }
}

/// One decoded frame (payload still raw bytes).
pub struct Frame {
    pub kind: FrameKind,
    pub payload: Vec<u8>,
}

fn bad(msg: String) -> io::Error {
    io::Error::new(ErrorKind::InvalidData, msg)
}

/// Write one frame (header + payload) to `w`.
pub fn write_frame(w: &mut impl Write, kind: FrameKind, payload: &[u8]) -> io::Result<()> {
    if payload.len() > MAX_FRAME_LEN {
        return Err(bad(format!("payload {} over cap", payload.len())));
    }
    let mut header = [0u8; HEADER_LEN];
    header[0] = WIRE_VERSION;
    header[1] = kind.byte();
    header[2..6].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&header)?;
    w.write_all(payload)?;
    w.flush()
}

/// Blocking read of one complete frame — the loadgen client's reader
/// path (the socket is owned by one thread, so parking mid-frame is
/// fine there).
pub fn read_frame(r: &mut impl Read) -> io::Result<Frame> {
    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header)?;
    let (kind, len) = parse_header(&header)?;
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(Frame { kind, payload })
}

fn parse_header(h: &[u8; HEADER_LEN]) -> io::Result<(FrameKind, usize)> {
    if h[0] != WIRE_VERSION {
        return Err(bad(format!(
            "wire version {} (server speaks {WIRE_VERSION})",
            h[0]
        )));
    }
    let kind = FrameKind::from_byte(h[1])?;
    let len = u32::from_le_bytes([h[2], h[3], h[4], h[5]]) as usize;
    if len > MAX_FRAME_LEN {
        return Err(bad(format!("frame length {len} over cap")));
    }
    Ok((kind, len))
}

/// Incremental frame decoder for non-blocking sockets: feed whatever
/// bytes arrived with [`FrameBuf::extend`], pull complete frames with
/// [`FrameBuf::next_frame`]. Frame alignment survives arbitrarily split
/// reads because undecoded bytes simply wait in the buffer.
#[derive(Default)]
pub struct FrameBuf {
    buf: Vec<u8>,
}

impl FrameBuf {
    /// An empty buffer.
    pub fn new() -> Self {
        FrameBuf::default()
    }

    /// Append freshly read bytes.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete frame, if a whole one has arrived. An error
    /// is connection-fatal (bad version/kind/length): the caller answers
    /// with an error frame and drops the connection.
    pub fn next_frame(&mut self) -> io::Result<Option<Frame>> {
        if self.buf.len() < HEADER_LEN {
            return Ok(None);
        }
        let mut header = [0u8; HEADER_LEN];
        header.copy_from_slice(&self.buf[..HEADER_LEN]);
        let (kind, len) = parse_header(&header)?;
        if self.buf.len() < HEADER_LEN + len {
            return Ok(None);
        }
        let payload = self.buf[HEADER_LEN..HEADER_LEN + len].to_vec();
        self.buf.drain(..HEADER_LEN + len);
        Ok(Some(Frame { kind, payload }))
    }
}

// ---------------------------------------------------------------------------
// Payloads
// ---------------------------------------------------------------------------

/// One operation inside a client request: which tenant/model it runs,
/// its SLO, and the seed the server expands into the input row (rows are
/// generated server-side — the bench wire carries intent, not tensors).
#[derive(Debug, Clone, PartialEq)]
pub struct WireOp {
    pub tenant: u32,
    pub model: String,
    /// Latency SLO, µs from server-side arrival.
    pub slo_us: f64,
    pub class: SloClass,
    /// Input-row seed (`golden::gen_hash01(d_in, seed)` server-side).
    pub seed: u64,
}

/// A client request frame: one op or a client-side batch of many. The
/// server decomposes the batch at intake and answers with exactly ONE
/// [`WireReply`] once every member reached a terminal state.
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    /// Client-chosen correlation id, echoed verbatim in the reply.
    pub id: u64,
    pub ops: Vec<WireOp>,
}

/// Terminal status of one op in a reply — the partial-accept contract:
/// some members of a batch may complete while others are rejected or
/// fail, and each reports its own outcome.
#[derive(Debug, Clone, PartialEq)]
pub enum WireOpStatus {
    Ok { latency_us: f64, met_deadline: bool },
    Rejected { reason: String },
    Failed,
}

/// The single reply to a [`WireRequest`], `ops` aligned index-for-index
/// with the request's ops.
#[derive(Debug, Clone, PartialEq)]
pub struct WireReply {
    pub id: u64,
    pub ops: Vec<WireOpStatus>,
}

/// Encode a request payload (JSON bytes; frame it with
/// [`write_frame`]`(…, FrameKind::Request, …)`).
pub fn encode_request(req: &WireRequest) -> Vec<u8> {
    let ops: Vec<Json> = req
        .ops
        .iter()
        .map(|op| {
            obj(vec![
                ("tenant", Json::Num(op.tenant as f64)),
                ("model", Json::Str(op.model.clone())),
                ("slo_us", Json::Num(op.slo_us)),
                ("class", Json::Str(op.class.name().to_string())),
                ("seed", Json::Num(op.seed as f64)),
            ])
        })
        .collect();
    obj(vec![
        ("id", Json::Num(req.id as f64)),
        ("ops", Json::Arr(ops)),
    ])
    .to_string_compact()
    .into_bytes()
}

/// Decode a request payload.
pub fn decode_request(payload: &[u8]) -> io::Result<WireRequest> {
    let text = std::str::from_utf8(payload).map_err(|_| bad("non-utf8 payload".into()))?;
    let j = Json::parse(text).map_err(|e| bad(format!("{e}")))?;
    let id = j.req_u64("id").map_err(|e| bad(format!("{e}")))?;
    let ops_json = j
        .get("ops")
        .and_then(Json::as_arr)
        .ok_or_else(|| bad("missing 'ops' array".into()))?;
    let mut ops = Vec::with_capacity(ops_json.len());
    for op in ops_json {
        let class_name = op.req_str("class").map_err(|e| bad(format!("{e}")))?;
        let class = SloClass::parse(&class_name)
            .ok_or_else(|| bad(format!("unknown class '{class_name}'")))?;
        ops.push(WireOp {
            tenant: op.req_u64("tenant").map_err(|e| bad(format!("{e}")))? as u32,
            model: op.req_str("model").map_err(|e| bad(format!("{e}")))?,
            slo_us: op.req_f64("slo_us").map_err(|e| bad(format!("{e}")))?,
            class,
            seed: op.req_u64("seed").map_err(|e| bad(format!("{e}")))?,
        });
    }
    Ok(WireRequest { id, ops })
}

/// Encode a reply payload.
pub fn encode_reply(reply: &WireReply) -> Vec<u8> {
    let ops: Vec<Json> = reply
        .ops
        .iter()
        .map(|s| match s {
            WireOpStatus::Ok {
                latency_us,
                met_deadline,
            } => obj(vec![
                ("status", Json::Str("ok".into())),
                ("latency_us", Json::Num(*latency_us)),
                ("met_deadline", Json::Bool(*met_deadline)),
            ]),
            WireOpStatus::Rejected { reason } => obj(vec![
                ("status", Json::Str("rejected".into())),
                ("reason", Json::Str(reason.clone())),
            ]),
            WireOpStatus::Failed => obj(vec![("status", Json::Str("failed".into()))]),
        })
        .collect();
    obj(vec![
        ("id", Json::Num(reply.id as f64)),
        ("ops", Json::Arr(ops)),
    ])
    .to_string_compact()
    .into_bytes()
}

/// Decode a reply payload.
pub fn decode_reply(payload: &[u8]) -> io::Result<WireReply> {
    let text = std::str::from_utf8(payload).map_err(|_| bad("non-utf8 payload".into()))?;
    let j = Json::parse(text).map_err(|e| bad(format!("{e}")))?;
    let id = j.req_u64("id").map_err(|e| bad(format!("{e}")))?;
    let ops_json = j
        .get("ops")
        .and_then(Json::as_arr)
        .ok_or_else(|| bad("missing 'ops' array".into()))?;
    let mut ops = Vec::with_capacity(ops_json.len());
    for op in ops_json {
        let status = op.req_str("status").map_err(|e| bad(format!("{e}")))?;
        ops.push(match status.as_str() {
            "ok" => WireOpStatus::Ok {
                latency_us: op.req_f64("latency_us").map_err(|e| bad(format!("{e}")))?,
                met_deadline: matches!(op.get("met_deadline"), Some(Json::Bool(true))),
            },
            "rejected" => WireOpStatus::Rejected {
                reason: op.req_str("reason").map_err(|e| bad(format!("{e}")))?,
            },
            "failed" => WireOpStatus::Failed,
            other => return Err(bad(format!("unknown status '{other}'"))),
        });
    }
    Ok(WireReply { id, ops })
}

/// Frame an error message (sent before the server closes a broken
/// connection; the payload is the bare message string as JSON).
pub fn encode_error(msg: &str) -> Vec<u8> {
    let mut m = BTreeMap::new();
    m.insert("error".to_string(), Json::Str(msg.to_string()));
    Json::Obj(m).to_string_compact().into_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_request() -> WireRequest {
        WireRequest {
            id: 42,
            ops: vec![
                WireOp {
                    tenant: 0,
                    model: "mlp_small".into(),
                    slo_us: 25_000.0,
                    class: SloClass::Critical,
                    seed: 7,
                },
                WireOp {
                    tenant: 3,
                    model: "gemmnet6".into(),
                    slo_us: 60_000.0,
                    class: SloClass::BestEffort,
                    seed: 8,
                },
            ],
        }
    }

    #[test]
    fn request_round_trips_through_a_frame() {
        let req = sample_request();
        let mut wire: Vec<u8> = Vec::new();
        write_frame(&mut wire, FrameKind::Request, &encode_request(&req)).unwrap();
        let frame = read_frame(&mut wire.as_slice()).unwrap();
        assert_eq!(frame.kind, FrameKind::Request);
        assert_eq!(decode_request(&frame.payload).unwrap(), req);
    }

    #[test]
    fn reply_round_trips_with_partial_accept_statuses() {
        let reply = WireReply {
            id: 42,
            ops: vec![
                WireOpStatus::Ok {
                    latency_us: 1_234.5,
                    met_deadline: true,
                },
                WireOpStatus::Rejected {
                    reason: "queue_full".into(),
                },
                WireOpStatus::Failed,
            ],
        };
        let decoded = decode_reply(&encode_reply(&reply)).unwrap();
        assert_eq!(decoded, reply);
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let mut wire: Vec<u8> = Vec::new();
        write_frame(&mut wire, FrameKind::Request, b"{}").unwrap();
        wire[0] = 2; // future version
        let err = read_frame(&mut wire.as_slice()).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidData);
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn oversized_length_is_rejected_without_allocating() {
        let mut header = [0u8; HEADER_LEN];
        header[0] = WIRE_VERSION;
        header[1] = FrameKind::Request.byte();
        header[2..6].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut header.as_slice()).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidData);
    }

    #[test]
    fn frame_buf_reassembles_split_and_coalesced_frames() {
        let req = sample_request();
        let mut wire: Vec<u8> = Vec::new();
        // two frames back to back, then fed one byte at a time
        write_frame(&mut wire, FrameKind::Request, &encode_request(&req)).unwrap();
        write_frame(&mut wire, FrameKind::Reply, b"{\"id\":1,\"ops\":[]}").unwrap();
        let mut buf = FrameBuf::new();
        let mut frames = Vec::new();
        for b in &wire {
            buf.extend(std::slice::from_ref(b));
            while let Some(f) = buf.next_frame().unwrap() {
                frames.push(f);
            }
        }
        assert_eq!(frames.len(), 2);
        assert_eq!(decode_request(&frames[0].payload).unwrap(), req);
        assert_eq!(frames[1].kind, FrameKind::Reply);
        // and in one gulp
        let mut buf = FrameBuf::new();
        buf.extend(&wire);
        assert!(buf.next_frame().unwrap().is_some());
        assert!(buf.next_frame().unwrap().is_some());
        assert!(buf.next_frame().unwrap().is_none());
    }

    #[test]
    fn frame_buf_surfaces_bad_header_as_fatal() {
        let mut buf = FrameBuf::new();
        buf.extend(&[9, 0, 0, 0, 0, 0]); // bad version
        assert!(buf.next_frame().is_err());
    }
}
