//! Multi-tenant model serving over the OoO JIT runtime.
//!
//! The serving layer is ONE event loop ([`engine::Engine`]) over the one
//! scheduler in this repo (`compiler::{window, scheduler, jit}`):
//! requests become `DispatchRequest`s with attached row payloads, each
//! (tenant, model) pair is a stream, each model a coalescing group, and
//! every hold/launch decision is the JIT core's. Packs execute as padded
//! compiled batch variants through the [`server::ServeExecutor`] adapter.
//! Python never runs here.
//!
//! * [`engine`] — the unified serving loop: a [`engine::Clock`] ×
//!   [`engine::LaunchStage`] pipeline (virtual or wall time × device
//!   timelines, inline execution, or a stateful worker pool), with
//!   placement/rebalance and the admission frontend as orthogonal
//!   options. See its module docs for the full mode matrix;
//! * [`server`] — policies, backends, and the thin per-mode constructors
//!   (`replay`, `replay_placed`, `run_realtime`, `run_realtime_pooled`,
//!   `run_realtime_placed`) over the engine;
//! * [`metrics`] — per-tenant latency histograms, SLO attainment,
//!   batch-occupancy accounting, JIT pack stats, per-device utilization,
//!   admission-decision latency and channel-wait histograms;
//! * [`admission`] — bounded queues + drop policy (backpressure), sharing
//!   the scheduler's service-time estimator (drain priced per launch,
//!   elapsed execution subtracted, divided across a group's replicas);
//! * [`frontend`] — the async admission stage: a dedicated thread owns
//!   the gate and prices requests against the `AdmissionView` snapshot
//!   the engine publishes each iteration, so tenant accept/reject never
//!   waits on an engine iteration (wall-clock runs only; the
//!   deterministic replays keep the synchronous gate). Gate counters are
//!   compacted epoch-wise under tenant churn;
//! * [`intake`] — the network front door: a framed TCP protocol with
//!   client-side batching, a sharded pool of socket workers feeding the
//!   frontend, per-batch reply tracking, and the wire load generator.

pub mod admission;
pub mod engine;
pub mod frontend;
pub mod intake;
pub mod metrics;
pub mod server;

pub use engine::{
    Clock, Engine, EngineConfig, InlineStage, LaunchStage, Placement, PoolStage,
    StageDone, TimelineStage, VirtualClock, WallClock,
};
pub use frontend::{AdmissionView, FrontendGate, GroupView, ViewCell};
pub use metrics::{DeviceMetrics, ServeMetrics};
pub use server::{
    BatchPolicy, ModelBackend, ModelSlot, ServeExecutor, ServeReport, Server, SimBackend,
};
