//! Multi-tenant model serving over the OoO JIT runtime.
//!
//! The serving layer is the *model-granularity* deployment of the paper's
//! scheduler: requests from independent tenants are EDF-ordered, held in a
//! bounded coalescing window, and coalesced into the smallest compiled
//! batch variant (the model-level analogue of superkernel packing; the
//! kernel-level path is exercised through `compiler::jit` +
//! `runtime::executor`). Python never runs here.
//!
//! * [`server`] — the serving loop: virtual-paced trace replay (benches,
//!   reproducible) and a threaded real-time mode (tenant threads → batcher
//!   thread → executor);
//! * [`metrics`] — per-tenant latency histograms, SLO attainment,
//!   batch-occupancy accounting;
//! * [`admission`] — bounded queues + drop policy (backpressure).

pub mod admission;
pub mod metrics;
pub mod server;

pub use metrics::ServeMetrics;
pub use server::{BatchPolicy, ServeReport, Server};
