//! Multi-tenant model serving over the OoO JIT runtime.
//!
//! The serving layer is a *thin driver* over the one scheduler in this
//! repo (`compiler::{window, scheduler, jit}`): requests become
//! `DispatchRequest`s with attached row payloads, each (tenant, model)
//! pair is a stream, each model a coalescing group, and every hold/launch
//! decision is the JIT core's. Packs execute as padded compiled batch
//! variants through the [`server::ServeExecutor`] adapter. Python never
//! runs here.
//!
//! * [`server`] — the serving drivers: virtual-paced trace replay
//!   (benches, reproducible), the placement-aware multi-device replay
//!   (`replay_placed`), an inline real-time mode, and the concurrent
//!   real-time modes whose launch stage routes through the
//!   [`crate::placement`] table (least-loaded replica per launch,
//!   rebalancer-driven replication of hot model groups);
//! * [`metrics`] — per-tenant latency histograms, SLO attainment,
//!   batch-occupancy accounting, JIT pack stats, per-device utilization,
//!   admission-decision latency and channel-wait histograms;
//! * [`admission`] — bounded queues + drop policy (backpressure), sharing
//!   the scheduler's service-time estimator (drain priced per launch,
//!   elapsed execution subtracted, divided across a group's replicas);
//! * [`frontend`] — the async admission stage: a dedicated thread owns
//!   the gate and prices requests against the `AdmissionView` snapshot
//!   the scheduler publishes each iteration, so tenant accept/reject
//!   never waits on a scheduler iteration (wall-clock drivers only; the
//!   deterministic replays keep the synchronous gate).

pub mod admission;
pub mod frontend;
pub mod metrics;
pub mod server;

pub use frontend::{AdmissionView, FrontendGate, GroupView, ViewCell};
pub use metrics::{DeviceMetrics, ServeMetrics};
pub use server::{
    BatchPolicy, ModelBackend, ModelSlot, ServeExecutor, ServeReport, Server, SimBackend,
};
