//! Multi-tenant model serving over the OoO JIT runtime.
//!
//! The serving layer is a *thin driver* over the one scheduler in this
//! repo (`compiler::{window, scheduler, jit}`): requests become
//! `DispatchRequest`s with attached row payloads, each (tenant, model)
//! pair is a stream, each model a coalescing group, and every hold/launch
//! decision is the JIT core's. Packs execute as padded compiled batch
//! variants through the [`server::ServeExecutor`] adapter. Python never
//! runs here.
//!
//! * [`server`] — the serving drivers: virtual-paced trace replay
//!   (benches, reproducible), an inline real-time mode, and a concurrent
//!   real-time mode with per-model worker backends;
//! * [`metrics`] — per-tenant latency histograms, SLO attainment,
//!   batch-occupancy accounting, JIT pack stats;
//! * [`admission`] — bounded queues + drop policy (backpressure), sharing
//!   the scheduler's service-time estimator.

pub mod admission;
pub mod metrics;
pub mod server;

pub use metrics::ServeMetrics;
pub use server::{
    BatchPolicy, ModelBackend, ModelSlot, ServeExecutor, ServeReport, Server, SimBackend,
};
