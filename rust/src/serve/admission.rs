//! Admission control: bounded per-model queues with a drop-oldest-deadline
//! policy under overload (backpressure toward the client, §3's
//! peak-provisioning discussion).

/// Admission decision for an incoming request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admit {
    /// Enqueue.
    Accept,
    /// Reject (queue full and request is not more urgent than the tail).
    Reject,
}

/// Bounded-queue admission controller.
#[derive(Debug, Clone)]
pub struct Admission {
    /// Max outstanding requests per model (queued + in flight).
    pub max_queue: usize,
}

impl Default for Admission {
    fn default() -> Self {
        Admission { max_queue: 256 }
    }
}

impl Admission {
    /// New controller.
    pub fn new(max_queue: usize) -> Self {
        Admission { max_queue }
    }

    /// Decide for a group currently holding `queued` un-issued requests
    /// and `inflight` issued-but-unfinished ones. The two are separate
    /// inputs because they back two different contracts:
    ///
    /// * **Backpressure bound**: total outstanding work (`queued +
    ///   inflight`) is capped at `max_queue` — launches on the device
    ///   still owe service time, so ignoring them would let the window
    ///   absorb unbounded doomed work under the concurrent launch stage.
    /// * **Doomed-shed escape hatch**: a request whose deadline is
    ///   already unreachable (`slack_after_drain_us < 0`) is shed eagerly
    ///   *only when real work is queued behind the gate* (§5.2
    ///   reprioritization — a doomed request has the earliest deadline,
    ///   so EDF would run it first and delay every queued request). With
    ///   an empty queue there is nothing for it to delay: in-flight
    ///   launches are already on the device and cannot be displaced, so
    ///   the doomed request still runs and the client gets a late answer
    ///   rather than none. (Folding `inflight` into the old single
    ///   `depth` argument silently disabled this hatch whenever any
    ///   launch was in flight.)
    pub fn decide(&self, queued: usize, inflight: usize, slack_after_drain_us: f64) -> Admit {
        if queued + inflight >= self.max_queue {
            return Admit::Reject;
        }
        if slack_after_drain_us < 0.0 && queued > 0 {
            // already doomed and there is real queued work to protect
            return Admit::Reject;
        }
        Admit::Accept
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepts_with_room_and_slack() {
        let a = Admission::new(4);
        assert_eq!(a.decide(0, 0, 10_000.0), Admit::Accept);
        assert_eq!(a.decide(3, 0, 0.0), Admit::Accept);
    }

    #[test]
    fn rejects_when_full() {
        let a = Admission::new(4);
        assert_eq!(a.decide(4, 0, 1e9), Admit::Reject);
        // the outstanding bound counts in-flight launches too: work on
        // the device still owes service time
        assert_eq!(a.decide(2, 2, 1e9), Admit::Reject);
        assert_eq!(a.decide(0, 4, 1e9), Admit::Reject);
    }

    #[test]
    fn sheds_doomed_requests_under_load() {
        let a = Admission::new(4);
        assert_eq!(a.decide(2, 0, -1.0), Admit::Reject);
        // but a doomed request into an empty queue still runs (nothing to
        // protect; client gets a late answer rather than none)
        assert_eq!(a.decide(0, 0, -1.0), Admit::Accept);
        // ... and in-flight launches don't close the hatch: they are
        // already on the device, a doomed newcomer cannot delay them
        assert_eq!(a.decide(0, 3, -1.0), Admit::Accept);
    }
}
