//! Admission control: bounded per-model queues with a drop-oldest-deadline
//! policy under overload (backpressure toward the client, §3's
//! peak-provisioning discussion).
//!
//! Class-aware since the SLO-class refactor: critical and standard
//! traffic keep the original pricing; best-effort requests are capped at
//! a configurable share of the queue and are always shed once doomed
//! (no empty-queue escape hatch — a best-effort client retries, it does
//! not need a guaranteed late answer).

use crate::compiler::ir::SloClass;

/// Admission decision for an incoming request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admit {
    /// Enqueue.
    Accept,
    /// Reject (queue full and request is not more urgent than the tail).
    Reject,
}

/// Bounded-queue admission controller.
#[derive(Debug, Clone)]
pub struct Admission {
    /// Max outstanding requests per model (queued + in flight).
    pub max_queue: usize,
    /// Fraction of `max_queue` available to best-effort traffic: a
    /// best-effort request is rejected once outstanding work reaches
    /// `max_queue × be_queue_share`, reserving the rest of the queue for
    /// critical/standard tenants under load.
    pub be_queue_share: f64,
}

impl Default for Admission {
    fn default() -> Self {
        Admission {
            max_queue: 256,
            be_queue_share: 0.5,
        }
    }
}

impl Admission {
    /// New controller (default best-effort share).
    pub fn new(max_queue: usize) -> Self {
        Admission {
            max_queue,
            ..Admission::default()
        }
    }

    /// Decide for a group currently holding `queued` un-issued requests
    /// and `inflight` issued-but-unfinished ones. The two are separate
    /// inputs because they back two different contracts:
    ///
    /// * **Backpressure bound**: total outstanding work (`queued +
    ///   inflight`) is capped at `max_queue` — launches on the device
    ///   still owe service time, so ignoring them would let the window
    ///   absorb unbounded doomed work under the concurrent launch stage.
    /// * **Doomed-shed escape hatch**: a request whose deadline is
    ///   already unreachable (`slack_after_drain_us < 0`) is shed eagerly
    ///   *only when real work is queued behind the gate* (§5.2
    ///   reprioritization — a doomed request has the earliest deadline,
    ///   so EDF would run it first and delay every queued request). With
    ///   an empty queue there is nothing for it to delay: in-flight
    ///   launches are already on the device and cannot be displaced, so
    ///   the doomed request still runs and the client gets a late answer
    ///   rather than none. (Folding `inflight` into the old single
    ///   `depth` argument silently disabled this hatch whenever any
    ///   launch was in flight.)
    pub fn decide(&self, queued: usize, inflight: usize, slack_after_drain_us: f64) -> Admit {
        if queued + inflight >= self.max_queue {
            return Admit::Reject;
        }
        if slack_after_drain_us < 0.0 && queued > 0 {
            // already doomed and there is real queued work to protect
            return Admit::Reject;
        }
        Admit::Accept
    }

    /// Outstanding-work cap for a class: best-effort stops at its queue
    /// share, everything else at `max_queue`.
    pub fn cap_of(&self, class: SloClass) -> usize {
        match class {
            SloClass::BestEffort => {
                ((self.max_queue as f64 * self.be_queue_share) as usize).max(1)
            }
            _ => self.max_queue,
        }
    }

    /// Class-aware decision — the one both gates call. Critical and
    /// standard reproduce [`Admission::decide`] exactly; best-effort is
    /// capped at its queue share and doomed best-effort is always shed
    /// (the empty-queue escape hatch is a latency-class courtesy).
    pub fn decide_class(
        &self,
        class: SloClass,
        queued: usize,
        inflight: usize,
        slack_after_drain_us: f64,
    ) -> Admit {
        if class == SloClass::BestEffort {
            if queued + inflight >= self.cap_of(class) {
                return Admit::Reject;
            }
            if slack_after_drain_us < 0.0 {
                return Admit::Reject;
            }
            return Admit::Accept;
        }
        self.decide(queued, inflight, slack_after_drain_us)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepts_with_room_and_slack() {
        let a = Admission::new(4);
        assert_eq!(a.decide(0, 0, 10_000.0), Admit::Accept);
        assert_eq!(a.decide(3, 0, 0.0), Admit::Accept);
    }

    #[test]
    fn rejects_when_full() {
        let a = Admission::new(4);
        assert_eq!(a.decide(4, 0, 1e9), Admit::Reject);
        // the outstanding bound counts in-flight launches too: work on
        // the device still owes service time
        assert_eq!(a.decide(2, 2, 1e9), Admit::Reject);
        assert_eq!(a.decide(0, 4, 1e9), Admit::Reject);
    }

    #[test]
    fn sheds_doomed_requests_under_load() {
        let a = Admission::new(4);
        assert_eq!(a.decide(2, 0, -1.0), Admit::Reject);
        // but a doomed request into an empty queue still runs (nothing to
        // protect; client gets a late answer rather than none)
        assert_eq!(a.decide(0, 0, -1.0), Admit::Accept);
        // ... and in-flight launches don't close the hatch: they are
        // already on the device, a doomed newcomer cannot delay them
        assert_eq!(a.decide(0, 3, -1.0), Admit::Accept);
    }

    #[test]
    fn best_effort_capped_at_queue_share() {
        let a = Admission::new(8); // BE cap = 8 × 0.5 = 4
        assert_eq!(a.cap_of(SloClass::BestEffort), 4);
        assert_eq!(a.cap_of(SloClass::Critical), 8);
        // at 4 outstanding: BE sheds, critical/standard still accepted
        assert_eq!(a.decide_class(SloClass::BestEffort, 3, 1, 1e9), Admit::Reject);
        assert_eq!(a.decide_class(SloClass::Critical, 3, 1, 1e9), Admit::Accept);
        assert_eq!(a.decide_class(SloClass::Standard, 3, 1, 1e9), Admit::Accept);
        // under the share: BE accepted
        assert_eq!(a.decide_class(SloClass::BestEffort, 2, 1, 1e9), Admit::Accept);
    }

    #[test]
    fn doomed_best_effort_always_shed() {
        let a = Admission::new(8);
        // no empty-queue escape hatch for best-effort
        assert_eq!(a.decide_class(SloClass::BestEffort, 0, 0, -1.0), Admit::Reject);
        // the hatch survives for the latency classes
        assert_eq!(a.decide_class(SloClass::Critical, 0, 0, -1.0), Admit::Accept);
        assert_eq!(a.decide_class(SloClass::Standard, 0, 0, -1.0), Admit::Accept);
    }

    #[test]
    fn standard_class_decision_is_the_legacy_decision() {
        let a = Admission::new(4);
        for (q, i, s) in [(0usize, 0usize, 10_000.0), (2, 2, 1e9), (2, 0, -1.0), (0, 3, -1.0)] {
            assert_eq!(a.decide_class(SloClass::Standard, q, i, s), a.decide(q, i, s));
            assert_eq!(a.decide_class(SloClass::Critical, q, i, s), a.decide(q, i, s));
        }
    }
}
