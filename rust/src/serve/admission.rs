//! Admission control: bounded per-model queues with a drop-oldest-deadline
//! policy under overload (backpressure toward the client, §3's
//! peak-provisioning discussion).

/// Admission decision for an incoming request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admit {
    /// Enqueue.
    Accept,
    /// Reject (queue full and request is not more urgent than the tail).
    Reject,
}

/// Bounded-queue admission controller.
#[derive(Debug, Clone)]
pub struct Admission {
    /// Max queued requests per model.
    pub max_queue: usize,
}

impl Default for Admission {
    fn default() -> Self {
        Admission { max_queue: 256 }
    }
}

impl Admission {
    /// New controller.
    pub fn new(max_queue: usize) -> Self {
        Admission { max_queue }
    }

    /// Decide for a queue currently holding `depth` requests. A request
    /// that would still meet its deadline after the estimated queue drain
    /// (`drain_us`) is accepted while there is room; hopeless requests
    /// (deadline already unreachable) are rejected eagerly so they don't
    /// burn device time (§5.2 reprioritization).
    pub fn decide(&self, depth: usize, slack_after_drain_us: f64) -> Admit {
        if depth >= self.max_queue {
            return Admit::Reject;
        }
        if slack_after_drain_us < 0.0 && depth > 0 {
            // already doomed and there is real work queued: shed it
            return Admit::Reject;
        }
        Admit::Accept
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepts_with_room_and_slack() {
        let a = Admission::new(4);
        assert_eq!(a.decide(0, 10_000.0), Admit::Accept);
        assert_eq!(a.decide(3, 0.0), Admit::Accept);
    }

    #[test]
    fn rejects_when_full() {
        let a = Admission::new(4);
        assert_eq!(a.decide(4, 1e9), Admit::Reject);
    }

    #[test]
    fn sheds_doomed_requests_under_load() {
        let a = Admission::new(4);
        assert_eq!(a.decide(2, -1.0), Admit::Reject);
        // but a doomed request into an empty queue still runs (nothing to
        // protect; client gets a late answer rather than none)
        assert_eq!(a.decide(0, -1.0), Admit::Accept);
    }
}
