//! Micro-benchmark harness + result tables (offline replacement for
//! criterion). Every paper figure/table bench links this: it provides
//! timing, table rendering aligned with the paper's rows, and JSON dumps
//! for post-processing.

use std::time::Instant;

use crate::util::json::{obj, Json};

/// Result of timing one closure.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    /// Median iteration time, µs.
    pub median_us: f64,
    /// Mean iteration time, µs.
    pub mean_us: f64,
    /// Min iteration time, µs.
    pub min_us: f64,
    /// Iterations measured.
    pub iters: usize,
}

/// Time `f` with warmup; returns robust statistics.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    Timing {
        median_us: median,
        mean_us: mean,
        min_us: samples[0],
        iters: samples.len(),
    }
}

/// A results table that renders fixed-width text and JSON.
#[derive(Debug, Clone)]
pub struct Table {
    /// Title (e.g. "Figure 4").
    pub title: String,
    /// Column headers.
    pub columns: Vec<String>,
    /// Rows of cells.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// New table.
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match column count).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity");
        self.rows.push(cells);
    }

    /// Render fixed-width text.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut s = format!("== {} ==\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        s.push_str(&fmt_row(&self.columns, &widths));
        s.push('\n');
        s.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        s.push('\n');
        for row in &self.rows {
            s.push_str(&fmt_row(row, &widths));
            s.push('\n');
        }
        s
    }

    /// JSON form (for EXPERIMENTS.md tooling).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("title", Json::Str(self.title.clone())),
            (
                "columns",
                Json::Arr(self.columns.iter().map(|c| Json::Str(c.clone())).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                        .collect(),
                ),
            ),
        ])
    }

    /// Print text and append the JSON line to `bench_results.jsonl` when
    /// `VLIW_BENCH_JSON=1`.
    pub fn emit(&self) {
        println!("{}", self.render());
        if std::env::var("VLIW_BENCH_JSON").as_deref() == Ok("1") {
            use std::io::Write;
            if let Ok(mut f) = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open("bench_results.jsonl")
            {
                let _ = writeln!(f, "{}", self.to_json().to_string_compact());
            }
        }
    }
}

/// Format a float with fixed decimals (table cells).
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Format µs as ms.
pub fn ms(us: f64) -> String {
    format!("{:.2}", us / 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_measures() {
        let t = time_it(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(t.iters, 5);
        assert!(t.min_us <= t.median_us);
        assert!(t.median_us < 1e5);
    }

    #[test]
    fn table_render_and_json() {
        let mut t = Table::new("Fig X", &["a", "bee"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let r = t.render();
        assert!(r.contains("Fig X") && r.contains("bee"));
        let j = t.to_json();
        assert_eq!(j.req_str("title").unwrap(), "Fig X");
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn row_arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(f(3.14159, 2), "3.14");
        assert_eq!(ms(1500.0), "1.50");
    }
}
