//! Workload generation: arrival processes, tenant specifications and
//! request traces — the synthetic stand-in for production inference streams
//! (the paper's own evaluation uses synthetic replicas, §4).

pub mod arrivals;
pub mod trace;
pub mod wire;

pub use arrivals::{Arrivals, Mmpp, Poisson};
pub use trace::{Request, TenantSpec, Trace};
pub use wire::{trace_to_wire, TimedWireRequest};
