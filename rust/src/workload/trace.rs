//! Request traces and tenant specifications.
//!
//! A *tenant* is one stream of execution in the paper's terminology: a
//! model, a latency SLO, and an arrival process. A *trace* merges all
//! tenants' requests into one time-ordered stream for replay against the
//! JIT or the baselines.

use crate::compiler::ir::SloClass;
use crate::util::rng::Rng;
use crate::workload::arrivals::{Arrivals, Mmpp, Poisson, Uniform};

/// Arrival process choice for a tenant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalKind {
    /// Poisson at `rate` req/s.
    Poisson,
    /// Bursty MMPP (calm = rate, burst = 10×rate, p_switch = 2%).
    Bursty,
    /// Fixed-gap arrivals.
    Uniform,
}

/// One tenant (stream of execution).
#[derive(Debug, Clone)]
pub struct TenantSpec {
    /// Tenant id (stream id).
    pub id: u32,
    /// Model served for this tenant (manifest model name or zoo name).
    pub model: String,
    /// Latency SLO, µs (deadline = arrival + slo).
    pub slo_us: u64,
    /// Mean request rate, req/s.
    pub rate: f64,
    /// Arrival process.
    pub kind: ArrivalKind,
    /// SLO class of every request this tenant issues (per-tenant class
    /// configuration — the scheduler-facing priority surface).
    pub class: SloClass,
}

impl TenantSpec {
    /// Convenience constructor (Standard class).
    pub fn new(id: u32, model: &str, slo_us: u64, rate: f64, kind: ArrivalKind) -> Self {
        Self {
            id,
            model: model.to_string(),
            slo_us,
            rate,
            kind,
            class: SloClass::Standard,
        }
    }

    /// Set the tenant's SLO class.
    pub fn with_class(mut self, class: SloClass) -> Self {
        self.class = class;
        self
    }
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Globally unique id.
    pub id: u64,
    /// Issuing tenant.
    pub tenant: u32,
    /// Model name.
    pub model: String,
    /// Arrival time, µs.
    pub arrival_us: f64,
    /// Absolute deadline, µs.
    pub deadline_us: f64,
    /// SLO class (copied from the issuing tenant's spec).
    pub class: SloClass,
}

impl Request {
    /// Remaining slack at time `now`, µs (negative = already late).
    pub fn slack_us(&self, now: f64) -> f64 {
        self.deadline_us - now
    }
}

/// A merged, time-ordered request trace.
#[derive(Debug, Clone)]
pub struct Trace {
    /// Requests sorted by arrival time.
    pub requests: Vec<Request>,
    /// The tenants that produced it.
    pub tenants: Vec<TenantSpec>,
}

impl Trace {
    /// Generate `per_tenant` requests from each tenant, merge and sort.
    pub fn generate(tenants: &[TenantSpec], per_tenant: usize, seed: u64) -> Trace {
        let mut requests = Vec::with_capacity(tenants.len() * per_tenant);
        let mut id = 0u64;
        for t in tenants {
            let tseed = Rng::new(seed ^ (t.id as u64).wrapping_mul(0x9E3779B97F4A7C15)).next_u64();
            let times = match t.kind {
                ArrivalKind::Poisson => Poisson::new(t.rate, tseed).times_us(per_tenant),
                ArrivalKind::Bursty => {
                    Mmpp::new(t.rate, t.rate * 10.0, 0.02, tseed).times_us(per_tenant)
                }
                ArrivalKind::Uniform => Uniform::new(t.rate).times_us(per_tenant),
            };
            for at in times {
                requests.push(Request {
                    id,
                    tenant: t.id,
                    model: t.model.clone(),
                    arrival_us: at,
                    deadline_us: at + t.slo_us as f64,
                    class: t.class,
                });
                id += 1;
            }
        }
        requests.sort_by(|a, b| a.arrival_us.partial_cmp(&b.arrival_us).unwrap());
        // re-number in arrival order so ids are monotone in time
        for (i, r) in requests.iter_mut().enumerate() {
            r.id = i as u64;
        }
        Trace {
            requests,
            tenants: tenants.to_vec(),
        }
    }

    /// Duration spanned by the trace, µs.
    pub fn span_us(&self) -> f64 {
        self.requests.last().map(|r| r.arrival_us).unwrap_or(0.0)
    }

    /// Aggregate offered load, req/s.
    pub fn offered_load(&self) -> f64 {
        if self.span_us() <= 0.0 {
            return 0.0;
        }
        self.requests.len() as f64 / (self.span_us() / 1e6)
    }

    /// Requests of one tenant.
    pub fn of_tenant(&self, id: u32) -> impl Iterator<Item = &Request> {
        self.requests.iter().filter(move |r| r.tenant == id)
    }
}

/// A standard multi-tenant setup used by examples/benches: `n` tenants with
/// mixed SLOs (tight 25 ms, medium 100 ms, relaxed 500 ms) round-robin over
/// the given models.
pub fn mixed_tenants(n: u32, models: &[&str], rate: f64) -> Vec<TenantSpec> {
    let slos = [25_000u64, 100_000, 500_000];
    (0..n)
        .map(|i| {
            TenantSpec::new(
                i,
                models[i as usize % models.len()],
                slos[i as usize % slos.len()],
                rate,
                if i % 4 == 3 {
                    ArrivalKind::Bursty
                } else {
                    ArrivalKind::Poisson
                },
            )
        })
        .collect()
}

/// The `slo-mix` bench workload: tenants cycle through the three SLO
/// classes with load skewed hard toward best-effort (4× the per-tenant
/// rate of the latency classes), so the batch tier saturates the device
/// while critical/standard tenants depend on class-weighted scheduling
/// for their slack. Best-effort SLOs are loose on purpose — their
/// attainment measures progress (bounded starvation), not latency.
pub fn slo_mix_tenants(n: u32, models: &[&str], rate: f64) -> Vec<TenantSpec> {
    (0..n)
        .map(|i| {
            let class = SloClass::from_index(i as usize % 3);
            let (slo_us, r) = match class {
                SloClass::Critical => (25_000u64, rate),
                SloClass::Standard => (100_000, rate),
                SloClass::BestEffort => (2_000_000, rate * 4.0),
            };
            TenantSpec::new(
                i,
                models[i as usize % models.len()],
                slo_us,
                r,
                ArrivalKind::Poisson,
            )
            .with_class(class)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tenants() -> Vec<TenantSpec> {
        vec![
            TenantSpec::new(0, "mlp_small", 25_000, 100.0, ArrivalKind::Poisson),
            TenantSpec::new(1, "gemmnet6", 100_000, 50.0, ArrivalKind::Bursty),
            TenantSpec::new(2, "mlp_large", 500_000, 20.0, ArrivalKind::Uniform),
        ]
    }

    #[test]
    fn trace_sorted_and_complete() {
        let t = Trace::generate(&tenants(), 200, 42);
        assert_eq!(t.requests.len(), 600);
        assert!(t
            .requests
            .windows(2)
            .all(|w| w[0].arrival_us <= w[1].arrival_us));
        // ids monotone
        assert!(t.requests.windows(2).all(|w| w[0].id < w[1].id));
        for id in 0..3 {
            assert_eq!(t.of_tenant(id).count(), 200);
        }
    }

    #[test]
    fn deadlines_encode_slo() {
        let t = Trace::generate(&tenants(), 50, 1);
        for r in t.of_tenant(0) {
            assert!((r.deadline_us - r.arrival_us - 25_000.0).abs() < 1e-6);
        }
    }

    #[test]
    fn deterministic_per_seed_and_tenant_independent() {
        let a = Trace::generate(&tenants(), 100, 9);
        let b = Trace::generate(&tenants(), 100, 9);
        assert_eq!(a.requests.len(), b.requests.len());
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.arrival_us, y.arrival_us);
            assert_eq!(x.tenant, y.tenant);
        }
        // different seed -> different trace
        let c = Trace::generate(&tenants(), 100, 10);
        assert!(a
            .requests
            .iter()
            .zip(&c.requests)
            .any(|(x, y)| x.arrival_us != y.arrival_us));
    }

    #[test]
    fn slack_sign() {
        let t = Trace::generate(&tenants(), 10, 2);
        let r = &t.requests[0];
        assert!(r.slack_us(r.arrival_us) > 0.0);
        assert!(r.slack_us(r.deadline_us + 1.0) < 0.0);
    }

    #[test]
    fn mixed_tenants_cycle_models_and_slos() {
        let ts = mixed_tenants(10, &["a", "b"], 50.0);
        assert_eq!(ts.len(), 10);
        assert_eq!(ts[0].model, "a");
        assert_eq!(ts[1].model, "b");
        assert_eq!(ts[0].slo_us, 25_000);
        assert_eq!(ts[1].slo_us, 100_000);
        assert_eq!(ts[3].kind, ArrivalKind::Bursty);
    }

    #[test]
    fn requests_carry_the_tenant_class() {
        let ts = vec![
            TenantSpec::new(0, "m", 25_000, 100.0, ArrivalKind::Poisson)
                .with_class(SloClass::Critical),
            TenantSpec::new(1, "m", 500_000, 100.0, ArrivalKind::Poisson)
                .with_class(SloClass::BestEffort),
        ];
        let t = Trace::generate(&ts, 20, 4);
        assert!(t.of_tenant(0).all(|r| r.class == SloClass::Critical));
        assert!(t.of_tenant(1).all(|r| r.class == SloClass::BestEffort));
    }

    #[test]
    fn slo_mix_cycles_classes_and_skews_load_to_best_effort() {
        let ts = slo_mix_tenants(6, &["a", "b"], 100.0);
        assert_eq!(ts.len(), 6);
        assert_eq!(ts[0].class, SloClass::Critical);
        assert_eq!(ts[1].class, SloClass::Standard);
        assert_eq!(ts[2].class, SloClass::BestEffort);
        assert_eq!(ts[3].class, SloClass::Critical);
        // the batch tier carries the bulk of the offered load
        assert!(ts[2].rate > 3.0 * ts[0].rate);
        // and its SLO is loose (it measures progress, not latency)
        assert!(ts[2].slo_us > 10 * ts[1].slo_us);
    }

    #[test]
    fn offered_load_close_to_nominal() {
        let ts = vec![TenantSpec::new(0, "m", 1_000_000, 200.0, ArrivalKind::Poisson)];
        let t = Trace::generate(&ts, 5_000, 3);
        let load = t.offered_load();
        assert!((load - 200.0).abs() < 15.0, "load={load}");
    }
}
