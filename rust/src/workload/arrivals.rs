//! Arrival processes: Poisson, MMPP (bursty), deterministic.
//!
//! §3: "requests arrive stochastically, occasional bursts in request volume
//! require overprovisioning" — the MMPP process reproduces exactly that
//! burstiness for the SLO-attainment experiments.

use crate::util::rng::Rng;

/// An arrival process: yields successive inter-arrival gaps in µs.
pub trait Arrivals {
    /// Next inter-arrival gap, µs.
    fn next_gap_us(&mut self) -> f64;

    /// Generate absolute arrival times for `n` requests starting at t=0.
    fn times_us(&mut self, n: usize) -> Vec<f64> {
        let mut t = 0.0;
        (0..n)
            .map(|_| {
                t += self.next_gap_us();
                t
            })
            .collect()
    }
}

/// Poisson arrivals at a fixed rate (requests/s).
#[derive(Debug, Clone)]
pub struct Poisson {
    rate_per_us: f64,
    rng: Rng,
}

impl Poisson {
    /// `rate` in requests per second.
    pub fn new(rate: f64, seed: u64) -> Self {
        assert!(rate > 0.0, "rate must be positive");
        Self {
            rate_per_us: rate / 1e6,
            rng: Rng::new(seed),
        }
    }
}

impl Arrivals for Poisson {
    fn next_gap_us(&mut self) -> f64 {
        self.rng.exp(self.rate_per_us)
    }
}

/// Markov-modulated Poisson process: two states (calm, burst) with
/// different rates; geometric dwell times. Models diurnal/bursty serving
/// traffic.
#[derive(Debug, Clone)]
pub struct Mmpp {
    calm_rate_us: f64,
    burst_rate_us: f64,
    /// probability of switching state after each arrival
    p_switch: f64,
    in_burst: bool,
    rng: Rng,
}

impl Mmpp {
    /// `calm_rate`/`burst_rate` in requests per second; `p_switch` the
    /// per-arrival state-flip probability.
    pub fn new(calm_rate: f64, burst_rate: f64, p_switch: f64, seed: u64) -> Self {
        assert!(calm_rate > 0.0 && burst_rate > 0.0);
        Self {
            calm_rate_us: calm_rate / 1e6,
            burst_rate_us: burst_rate / 1e6,
            p_switch: p_switch.clamp(0.0, 1.0),
            in_burst: false,
            rng: Rng::new(seed),
        }
    }
}

impl Arrivals for Mmpp {
    fn next_gap_us(&mut self) -> f64 {
        if self.rng.f64() < self.p_switch {
            self.in_burst = !self.in_burst;
        }
        let r = if self.in_burst {
            self.burst_rate_us
        } else {
            self.calm_rate_us
        };
        self.rng.exp(r)
    }
}

/// Deterministic (closed-loop / fixed-rate) arrivals.
#[derive(Debug, Clone)]
pub struct Uniform {
    gap_us: f64,
}

impl Uniform {
    /// `rate` in requests per second.
    pub fn new(rate: f64) -> Self {
        assert!(rate > 0.0);
        Self { gap_us: 1e6 / rate }
    }
}

impl Arrivals for Uniform {
    fn next_gap_us(&mut self) -> f64 {
        self.gap_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_mean_rate() {
        let mut p = Poisson::new(1000.0, 1); // 1000 req/s => mean gap 1000µs
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| p.next_gap_us()).sum::<f64>() / n as f64;
        assert!((mean - 1000.0).abs() < 20.0, "mean={mean}");
    }

    #[test]
    fn poisson_deterministic_by_seed() {
        let mut a = Poisson::new(10.0, 7);
        let mut b = Poisson::new(10.0, 7);
        for _ in 0..100 {
            assert_eq!(a.next_gap_us(), b.next_gap_us());
        }
    }

    #[test]
    fn times_are_monotone() {
        let mut p = Poisson::new(100.0, 3);
        let ts = p.times_us(1000);
        assert!(ts.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn mmpp_is_burstier_than_poisson() {
        // squared coefficient of variation of inter-arrivals: Poisson = 1,
        // MMPP > 1
        let cv2 = |gaps: &[f64]| {
            let m = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let v = gaps.iter().map(|g| (g - m) * (g - m)).sum::<f64>() / gaps.len() as f64;
            v / (m * m)
        };
        let mut p = Poisson::new(100.0, 5);
        let mut mm = Mmpp::new(20.0, 500.0, 0.02, 5);
        let gp: Vec<f64> = (0..30_000).map(|_| p.next_gap_us()).collect();
        let gm: Vec<f64> = (0..30_000).map(|_| mm.next_gap_us()).collect();
        assert!((cv2(&gp) - 1.0).abs() < 0.15, "poisson cv2={}", cv2(&gp));
        assert!(cv2(&gm) > 1.5, "mmpp cv2={}", cv2(&gm));
    }

    #[test]
    fn uniform_exact() {
        let mut u = Uniform::new(200.0);
        assert_eq!(u.next_gap_us(), 5000.0);
        assert_eq!(u.times_us(3), vec![5000.0, 10000.0, 15000.0]);
    }
}
