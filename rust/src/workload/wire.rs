//! Trace → wire-request encoding: turns a generated [`Trace`] into the
//! timed, client-batched frame schedule the load generator replays.
//! Shared by `vliwd loadgen`, `vliwd bench --wire`, and the loopback
//! e2e tests so they all speak the exact same workload.
//!
//! Client batching here models an application that amortises the wire:
//! each tenant's consecutive requests are chunked into groups of `batch`
//! and shipped as ONE wire request once the last member has "arrived"
//! (you cannot send a batch you have not finished composing). Intake
//! decomposes the batch again — re-coalescing across tenants is the
//! JIT's job, not the client's.

use crate::serve::intake::wire::{WireOp, WireRequest};
use crate::workload::trace::Trace;

/// One wire request with its send time on the replay clock.
#[derive(Debug, Clone)]
pub struct TimedWireRequest {
    /// Send time, µs from replay start (already compressed by the
    /// replay `speedup`).
    pub at_us: f64,
    /// Issuing tenant — the load generator pins tenants to connections
    /// with `tenant % conns`, preserving per-stream order.
    pub tenant: u32,
    pub req: WireRequest,
}

/// Chunk each tenant's ordered requests into client batches of `batch`
/// and time them: a batch ships at its LAST member's (compressed)
/// arrival. SLOs stay uncompressed, matching the trace replay in
/// `Engine::run_wall`. The result is merged and sorted by send time.
pub fn trace_to_wire(trace: &Trace, batch: usize, speedup: f64) -> Vec<TimedWireRequest> {
    let batch = batch.max(1);
    let mut out: Vec<TimedWireRequest> = Vec::with_capacity(trace.requests.len() / batch + 1);
    for t in &trace.tenants {
        let reqs: Vec<_> = trace.of_tenant(t.id).collect();
        for chunk in reqs.chunks(batch) {
            let ops = chunk
                .iter()
                .map(|r| WireOp {
                    tenant: r.tenant,
                    model: r.model.clone(),
                    slo_us: r.deadline_us - r.arrival_us,
                    class: r.class,
                    seed: r.id.wrapping_mul(7919),
                })
                .collect();
            out.push(TimedWireRequest {
                at_us: chunk.last().expect("non-empty chunk").arrival_us / speedup,
                tenant: t.id,
                req: WireRequest {
                    id: chunk[0].id,
                    ops,
                },
            });
        }
    }
    out.sort_by(|a, b| a.at_us.partial_cmp(&b.at_us).expect("finite send times"));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::{ArrivalKind, TenantSpec};

    fn trace() -> Trace {
        let tenants = vec![
            TenantSpec::new(0, "mlp_small", 25_000, 200.0, ArrivalKind::Poisson),
            TenantSpec::new(1, "gemmnet6", 100_000, 200.0, ArrivalKind::Uniform),
        ];
        Trace::generate(&tenants, 10, 7)
    }

    #[test]
    fn batch_one_is_request_per_op() {
        let t = trace();
        let wire = trace_to_wire(&t, 1, 1.0);
        assert_eq!(wire.len(), t.requests.len());
        assert!(wire.iter().all(|w| w.req.ops.len() == 1));
        assert!(wire.windows(2).all(|w| w[0].at_us <= w[1].at_us));
    }

    #[test]
    fn batches_chunk_per_tenant_and_ship_at_last_arrival() {
        let t = trace();
        let wire = trace_to_wire(&t, 4, 1.0);
        // 10 requests per tenant in chunks of 4 -> 3 wire requests each
        assert_eq!(wire.len(), 6);
        for w in &wire {
            assert!(w.req.ops.len() <= 4);
            // a batch never mixes tenants
            assert!(w.req.ops.iter().all(|o| o.tenant == w.tenant));
            // ships once the last member exists
            let arrivals: Vec<f64> = t
                .of_tenant(w.tenant)
                .filter(|r| w.req.ops.iter().any(|o| o.seed == r.id.wrapping_mul(7919)))
                .map(|r| r.arrival_us)
                .collect();
            assert_eq!(arrivals.len(), w.req.ops.len());
            let last = arrivals.iter().cloned().fold(0.0f64, f64::max);
            assert!((w.at_us - last).abs() < 1e-9);
        }
    }

    #[test]
    fn speedup_compresses_send_times_not_slos() {
        let t = trace();
        let w1 = trace_to_wire(&t, 2, 1.0);
        let w4 = trace_to_wire(&t, 2, 4.0);
        assert_eq!(w1.len(), w4.len());
        for (a, b) in w1.iter().zip(&w4) {
            assert!((a.at_us / 4.0 - b.at_us).abs() < 1e-9);
            for (x, y) in a.req.ops.iter().zip(&b.req.ops) {
                assert_eq!(x.slo_us, y.slo_us);
            }
        }
    }
}
