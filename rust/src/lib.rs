//! # vliw-jit — The OoO VLIW JIT Compiler for GPU Inference
//!
//! A full reproduction of *"The OoO VLIW JIT Compiler for GPU Inference"*
//! (Jain, Mo, Jain, Tumanov, Gonzalez, Stoica — UC Berkeley/MIT, 2019) as a
//! three-layer Rust + JAX + Pallas serving stack:
//!
//! * **Layer 1** (`python/compile/kernels/`) — Pallas superkernels: the
//!   `cublasSgemmBatched`-style coalesced GEMM and a fused linear layer,
//!   validated against pure-jnp oracles.
//! * **Layer 2** (`python/compile/model.py`) — JAX model graphs built from
//!   the L1 kernels, AOT-lowered to HLO text per (model, batch) variant.
//! * **Layer 3** (this crate) — the paper's contribution: an out-of-order,
//!   SLO-aware, VLIW-inspired JIT that **coalesces** shape-compatible
//!   kernels from independent execution streams into superkernels,
//!   **reorders** them in GPU space-time under per-stream deadlines, and
//!   **retunes** them with a co-tenancy-aware autotuner. Python never runs
//!   on the request path; compiled artifacts execute through the PJRT CPU
//!   client (`runtime::pjrt`), and V100-scale numbers come from the
//!   discrete-event GPU simulator (`gpu`).
//!
//! See `DESIGN.md` for the system inventory and the per-figure experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! ## Crate map
//!
//! | module | role |
//! |---|---|
//! | [`util`] | substrates built in-repo: PRNG, stats, JSON, CLI, threadpool, logging |
//! | [`analysis`] | the schedule verifier: plan verifier, launch-log auditor, architecture linter |
//! | [`gpu`] | V100-calibrated space-time GPU simulator (device, cost model, timeline, multiplexing) |
//! | [`model`] | DNN model zoo: per-layer GEMM shape extraction (Fig. 2/7 source data) |
//! | [`workload`] | arrival processes, tenant specs, trace generation/replay |
//! | [`compiler`] | the OoO VLIW JIT: IR, issue window, coalescer, scheduler, autotuner, clustering |
//! | [`estimate`] | the one cost model: tiered Measured/Tuned/Prior duration estimator + autotune artifact cache |
//! | [`runtime`] | artifact manifest + PJRT executor + golden self-checks |
//! | [`placement`] | device placement: fleet topology, group→device table, load rebalancer |
//! | [`serve`] | multi-tenant serving loop, metrics, admission control |
//! | [`bench`] | micro-benchmark harness (criterion replacement) |

pub mod analysis;
pub mod bench;
pub mod compiler;
pub mod estimate;
pub mod gpu;
pub mod model;
pub mod placement;
pub mod runtime;
pub mod serve;
pub mod util;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Artifact manifest missing/corrupt, or lookup failed.
    #[error("artifact error: {0}")]
    Artifact(String),
    /// PJRT / XLA runtime failure.
    #[error("xla error: {0}")]
    Xla(#[from] xla::Error),
    /// I/O failure (manifest, weights, traces).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    /// JSON parse failure.
    #[error("json error: {0}")]
    Json(String),
    /// Invalid configuration or argument.
    #[error("config error: {0}")]
    Config(String),
    /// Scheduling invariant violation / infeasible request.
    #[error("scheduler error: {0}")]
    Sched(String),
}

impl Error {
    /// Shorthand constructor for config errors.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
}
