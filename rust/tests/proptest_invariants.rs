//! Property-based tests over coordinator invariants (in-repo proptest
//! substitute: seeded random generation + shrink-free assertion loops, 100+
//! cases per property).

use vliw_jit::compiler::coalescer::{Coalescer, ShapeClass};
use vliw_jit::compiler::ir::{DispatchRequest, OpId, SloClass, StreamId, TensorOp};
use vliw_jit::compiler::jit::{JitCompiler, JitConfig, SimExecutor};
use vliw_jit::compiler::window::{OpState, Window};
use vliw_jit::gpu::cost::CostModel;
use vliw_jit::gpu::device::DeviceSpec;
use vliw_jit::gpu::kernel::{KernelDesc, LaunchConfig};
use vliw_jit::gpu::timeline::{SharingModel, SharingSim, SimKernel};
use vliw_jit::placement::{DeviceTopology, Placer, RebalanceConfig, Rebalancer};
use vliw_jit::util::rng::Rng;

fn rand_kernel(rng: &mut Rng) -> KernelDesc {
    KernelDesc::gemm(
        1 + rng.below(512) as u32,
        1 + rng.below(2048) as u32,
        1 + rng.below(512) as u32,
    )
}

// ---------------------------------------------------------------------------
// Coalescer properties
// ---------------------------------------------------------------------------

#[test]
fn prop_pack_partitions_ops() {
    // every input op appears in exactly one pack, and packs never exceed
    // max_problems
    let mut rng = Rng::new(0xA11CE);
    for case in 0..150 {
        let n = 1 + rng.below(40) as usize;
        let max_p = 1 + rng.below(8) as usize;
        let ops: Vec<TensorOp> = (0..n)
            .map(|i| TensorOp {
                id: OpId(i as u64),
                stream: StreamId(i as u32),
                seq: 0,
                kernel: rand_kernel(&mut rng),
                arrival_us: 0.0,
                deadline_us: 1e9,
                group: 0,
                tag: 0,
                independent: false,
                class: SloClass::Standard,
            })
            .collect();
        let refs: Vec<&TensorOp> = ops.iter().collect();
        let packs = Coalescer::new(max_p, 0.75).pack(&refs);
        let mut seen: Vec<u64> = packs
            .iter()
            .flat_map(|p| p.ops.iter().map(|o| o.0))
            .collect();
        seen.sort_unstable();
        assert_eq!(
            seen,
            (0..n as u64).collect::<Vec<_>>(),
            "case {case}: partition violated"
        );
        for p in &packs {
            assert!(p.problems() <= max_p, "case {case}: oversize pack");
            assert!(p.pack_efficiency() > 0.0 && p.pack_efficiency() <= 1.0 + 1e-9);
            // every member fits inside the pack's class
            for id in &p.ops {
                let op = &ops[id.0 as usize];
                assert!(op.kernel.m <= p.class.m);
                assert!(op.kernel.k <= p.class.k);
                assert!(op.kernel.n <= p.class.n);
            }
        }
    }
}

#[test]
fn prop_quantization_idempotent_and_monotone() {
    let mut rng = Rng::new(0xBEE);
    for _ in 0..300 {
        let k = rand_kernel(&mut rng);
        let c = ShapeClass::of(&k);
        // idempotent: quantizing the class shape returns itself
        let kc = KernelDesc::gemm(c.m, c.k, c.n);
        assert_eq!(ShapeClass::of(&kc), c);
        // contains the original
        assert!(c.m >= k.m && c.k >= k.k && c.n >= k.n);
        // within 2x in each dim
        assert!(c.m < 2 * k.m.max(1) || k.m <= 1);
        assert!(c.padding_overhead(&k) < 0.875 + 1e-9);
    }
}

// ---------------------------------------------------------------------------
// Window properties
// ---------------------------------------------------------------------------

#[test]
fn prop_window_program_order_per_stream() {
    // randomized submit/issue/complete interleavings never issue a stream's
    // ops out of order
    let mut rng = Rng::new(0xD00D);
    for case in 0..100 {
        let mut w = Window::new(256);
        let mut issued_seq: std::collections::HashMap<u32, u64> = Default::default();
        let mut inflight: Vec<OpId> = Vec::new();
        for _ in 0..200 {
            match rng.below(3) {
                0 => {
                    let stream = rng.below(5) as u32;
                    let _ = w.submit(
                        DispatchRequest::new(
                            StreamId(stream),
                            rand_kernel(&mut rng),
                            1e9,
                        ),
                        0.0,
                    );
                }
                1 => {
                    let ready: Vec<OpId> = w.ready().iter().map(|o| o.id).collect();
                    if let Some(&id) = ready.first() {
                        let op = w.get(id).unwrap().clone();
                        let last = issued_seq.entry(op.stream.0).or_insert(0);
                        assert!(
                            op.seq >= *last,
                            "case {case}: stream {} issued seq {} after {}",
                            op.stream.0,
                            op.seq,
                            last
                        );
                        *last = op.seq + 1;
                        w.issue(&[id]);
                        inflight.push(id);
                    }
                }
                _ => {
                    if !inflight.is_empty() {
                        let i = rng.below(inflight.len() as u64) as usize;
                        let id = inflight.swap_remove(i);
                        w.complete(id);
                    }
                }
            }
        }
    }
}

#[test]
fn prop_window_independent_ready_prefix_is_safe() {
    // with random independence flags, randomized issue order of ready ops
    // — plus random straggler evictions (requeue) — never lets a DEPENDENT
    // op issue while an earlier op of its stream is still pending
    // (independent ops are free to overtake)
    let mut rng = Rng::new(0x1DE9);
    for case in 0..100 {
        let mut w = Window::new(256);
        // (id, stream, seq) of submitted-but-unissued ops
        let mut pending: Vec<(OpId, u32, u64)> = Vec::new();
        let mut inflight: Vec<OpId> = Vec::new();
        for _ in 0..200 {
            match rng.below(4) {
                0 => {
                    let stream = rng.below(4) as u32;
                    let ind = rng.below(2) == 1;
                    if let Some(id) = w.submit(
                        DispatchRequest::new(
                            StreamId(stream),
                            rand_kernel(&mut rng),
                            1e9,
                        )
                        .with_independent(ind),
                        0.0,
                    ) {
                        let seq = w.get(id).unwrap().seq;
                        pending.push((id, stream, seq));
                    }
                }
                1 => {
                    let ready: Vec<OpId> = w.ready().iter().map(|o| o.id).collect();
                    if !ready.is_empty() {
                        let pick = rng.below(ready.len() as u64) as usize;
                        let id = ready[pick];
                        assert_eq!(w.state(id), Some(OpState::Ready));
                        let op = w.get(id).unwrap().clone();
                        if !op.independent {
                            assert!(
                                !pending.iter().any(|&(pid, s, seq)| pid != id
                                    && s == op.stream.0
                                    && seq < op.seq),
                                "case {case}: dependent op {id:?} ready over an \
                                 earlier pending op of stream {}",
                                op.stream.0
                            );
                        }
                        w.issue(&[id]);
                        pending.retain(|&(pid, _, _)| pid != id);
                        inflight.push(id);
                    }
                }
                2 => {
                    // straggler eviction: a random in-flight op re-enters
                    // its stream's pending queue in program order
                    if !inflight.is_empty() {
                        let i = rng.below(inflight.len() as u64) as usize;
                        let id = inflight.swap_remove(i);
                        let op = w.get(id).unwrap().clone();
                        w.requeue(id);
                        pending.push((id, op.stream.0, op.seq));
                    }
                }
                _ => {
                    if !inflight.is_empty() {
                        let i = rng.below(inflight.len() as u64) as usize;
                        let id = inflight.swap_remove(i);
                        w.complete(id);
                    }
                }
            }
        }
        // drain: bookkeeping must shrink back to zero with the work
        loop {
            let next = w.ready().first().map(|o| o.id);
            match next {
                Some(id) => {
                    w.issue(&[id]);
                    inflight.push(id);
                }
                None => break,
            }
        }
        for id in inflight {
            w.complete(id);
        }
        assert!(w.is_empty(), "case {case}: window drains");
        assert_eq!(w.tracked_streams(), 0, "case {case}: stream maps drain");
        assert_eq!(w.tracked_groups(), 0, "case {case}: group maps drain");
    }
}

// ---------------------------------------------------------------------------
// Placement properties
// ---------------------------------------------------------------------------

fn rand_topology(rng: &mut Rng) -> DeviceTopology {
    let pool = [
        DeviceSpec::v100(),
        DeviceSpec::t4(),
        DeviceSpec::k80(),
        DeviceSpec::tpuv2(),
    ];
    let n = 1 + rng.below(4) as usize;
    let specs: Vec<DeviceSpec> = (0..n)
        .map(|_| pool[rng.below(pool.len() as u64) as usize].clone())
        .collect();
    DeviceTopology::new(specs)
}

#[test]
fn prop_placement_table_is_total() {
    // every group maps to >= 1 live device straight out of the placer,
    // for random topologies and random cost profiles
    let mut rng = Rng::new(0x91ACE);
    for case in 0..150 {
        let topo = rand_topology(&mut rng);
        let ng = 1 + rng.below(16);
        let costs: Vec<(u64, f64)> = (0..ng)
            .map(|g| (g, rng.f64() * 2_000.0))
            .collect();
        let table = Placer::place(&costs, &topo);
        assert!(
            table.is_total(ng, topo.len()),
            "case {case}: non-total placement for {ng} groups on {} workers",
            topo.len()
        );
        // routing always lands on a live worker, replica or not
        let load = vec![0.0; topo.len()];
        for g in 0..ng + 3 {
            assert!(table.route(g, &load) < topo.len(), "case {case}");
        }
    }
}

#[test]
fn prop_rebalance_converges_without_thrashing() {
    // a stationary skewed load (one hot group, the rest cold) where each
    // window's observations follow the *current* table: rebalancing must
    // (a) keep the table total, (b) never exceed the per-window move
    // budget, and (c) quiesce — cumulative moves bounded well below one
    // per window — rather than oscillate groups between devices
    let mut rng = Rng::new(0xBA1A9CE);
    for case in 0..60 {
        let topo = rand_topology(&mut rng);
        let nw = topo.len();
        let ng = 1 + rng.below(10);
        let costs: Vec<(u64, f64)> = (0..ng)
            .map(|g| (g, 100.0 + rng.f64() * 1_000.0))
            .collect();
        let mut table = Placer::place(&costs, &topo);
        let cfg = RebalanceConfig::default();
        let window_us = cfg.window_us;
        let max_moves = cfg.max_moves_per_window as usize;
        let mut rb = Rebalancer::new(cfg, nw);
        let hot = rng.below(ng);
        let mut now = 0.0;
        let mut total_moves = 0usize;
        let windows = 40usize;
        for w in 0..windows {
            // synthesize a window of launches consistent with the table:
            // the hot group saturates its replicas, cold groups trickle
            for g in 0..ng {
                let reps = table.replicas_of(g).to_vec();
                assert!(!reps.is_empty(), "case {case} window {w}: totality");
                let busy = if g == hot {
                    0.9 * window_us
                } else {
                    0.04 * window_us
                };
                for r in &reps {
                    rb.observe_launch(g, *r, busy / reps.len() as f64);
                }
            }
            now += window_us;
            let actions = rb.maybe_rebalance(now, &mut table, &topo);
            assert!(
                actions.len() <= max_moves,
                "case {case} window {w}: {} moves > budget {max_moves}",
                actions.len()
            );
            total_moves += actions.len();
            assert!(
                table.is_total(ng, nw),
                "case {case} window {w}: rebalance broke totality"
            );
        }
        // replication is bounded by (groups x workers) and migration by
        // the strict-improvement rule; a thrashing rebalancer would move
        // every window and blow straight through this bound
        let bound = (ng as usize * nw + nw).min(windows / 2);
        assert!(
            total_moves <= bound,
            "case {case}: {total_moves} moves over {windows} windows (bound {bound}) — thrashing"
        );
    }
}

#[test]
fn prop_jit_conserves_ops_and_meets_generous_slos() {
    let mut rng = Rng::new(0xF00D);
    for case in 0..30 {
        let n = 5 + rng.below(40) as usize;
        let mut t = 0.0;
        let ops: Vec<(f64, DispatchRequest)> = (0..n)
            .map(|i| {
                t += rng.exp(1.0 / 300.0); // ~300µs mean gap
                (
                    t,
                    DispatchRequest::new(
                        StreamId((i % 6) as u32),
                        rand_kernel(&mut rng),
                        1e9, // generous
                    ),
                )
            })
            .collect();
        let mut jit = JitCompiler::new(JitConfig::default(), SimExecutor::v100());
        let done = jit.run_trace(ops);
        assert_eq!(done.len(), n, "case {case}: op conservation");
        assert_eq!(jit.stats.ops, n as u64);
        assert_eq!(jit.stats.slo_attainment(), 1.0, "case {case}");
        assert!(jit.stats.pack_efficiency() > 0.1);
        // completions non-decreasing in time
        let mut last = 0.0;
        for c in &done {
            assert!(c.done_us >= last);
            last = c.done_us;
        }
    }
}

#[test]
fn prop_jit_deterministic() {
    let mk = |seed| {
        let mut rng = Rng::new(seed);
        let ops: Vec<(f64, DispatchRequest)> = (0..25)
            .map(|i| {
                (
                    i as f64 * 100.0,
                    DispatchRequest::new(
                        StreamId((i % 4) as u32),
                        rand_kernel(&mut rng),
                        50_000.0,
                    ),
                )
            })
            .collect();
        let mut jit = JitCompiler::new(JitConfig::default(), SimExecutor::v100());
        let done = jit.run_trace(ops);
        (
            jit.stats.launches,
            done.iter().map(|c| c.done_us.to_bits()).collect::<Vec<_>>(),
        )
    };
    assert_eq!(mk(99), mk(99));
}

// ---------------------------------------------------------------------------
// Simulator properties
// ---------------------------------------------------------------------------

#[test]
fn prop_sharing_sim_conserves_work() {
    // total device-time consumed is at least the sum of isolated exec
    // times scaled by demand (no free lunch), and every kernel completes
    let cm = CostModel::v100();
    let mut rng = Rng::new(0xCAFE);
    for case in 0..50 {
        let n = 1 + rng.below(20) as usize;
        let kernels: Vec<SimKernel> = (0..n)
            .map(|i| SimKernel {
                id: i as u64,
                stream: i as u32,
                profile: cm.profile(&rand_kernel(&mut rng), &LaunchConfig::greedy()),
                arrival_us: rng.f64() * 1000.0,
            })
            .collect();
        let res = SharingSim::new(SharingModel::default()).run(&kernels);
        assert_eq!(res.completions.len(), n, "case {case}");
        // no kernel finishes faster than its isolated time
        for c in &res.completions {
            let k = kernels.iter().find(|k| k.id == c.id).unwrap();
            assert!(
                c.latency_us >= k.profile.duration_us * 0.999,
                "case {case}: kernel {} finished in {} < isolated {}",
                c.id,
                c.latency_us,
                k.profile.duration_us
            );
        }
        assert!(res.utilization <= 1.0 + 1e-9);
    }
}

#[test]
fn prop_time_mux_latency_monotone_in_position() {
    // under time multiplexing, simultaneously-arriving kernels complete in
    // issue order with non-decreasing latency
    let cm = CostModel::v100();
    let mut rng = Rng::new(0x7AB1E);
    for _ in 0..50 {
        let n = 2 + rng.below(12) as usize;
        let k = rand_kernel(&mut rng);
        let kernels: Vec<SimKernel> = (0..n)
            .map(|i| SimKernel {
                id: i as u64,
                stream: i as u32,
                profile: cm.profile(&k, &LaunchConfig::greedy()),
                arrival_us: 0.0,
            })
            .collect();
        let res = vliw_jit::gpu::timeline::run_time_mux(&kernels, 200.0);
        let mut last = 0.0;
        for c in &res.completions {
            assert!(c.latency_us >= last);
            last = c.latency_us;
        }
    }
}

// ---------------------------------------------------------------------------
// Admission frontend properties
// ---------------------------------------------------------------------------

use std::time::Instant;

use vliw_jit::compiler::scheduler::Policy;
use vliw_jit::serve::admission::{Admission, Admit};
use vliw_jit::serve::frontend::{
    snapshot_group, AdmissionView, FrontendGate, GateExtras, GateRequest, GroupView,
};
use vliw_jit::serve::{ModelSlot, ServeExecutor, SimBackend};

type ServeJit<'a> = JitCompiler<ServeExecutor<&'a mut SimBackend>, Vec<f32>>;

fn serve_jit(backend: &mut SimBackend, pack_cap: usize) -> ServeJit<'_> {
    let slots = vec![ModelSlot {
        name: "m".to_string(),
        d_in: 4,
        max_batch: 16,
    }];
    let mut coalescer = Coalescer::new(pack_cap, 1.0);
    coalescer.group_caps.insert(0, pack_cap);
    let cfg = JitConfig {
        policy: Policy {
            coalesce_window_us: 0.0,
            target_pack: 1,
            safety_margin_us: 0.0,
            ..Policy::default()
        },
        coalescer,
        window_capacity: 256,
        packing_overhead_us: 0.0,
    };
    JitCompiler::with_payloads(cfg, ServeExecutor::new(backend, slots))
}

/// The documented drain-pricing formula, written out independently of the
/// `GroupView` implementation (the synchronous gate's pre-refactor
/// arithmetic) — the oracle both gates must match.
fn reference_drain_est(
    jit: &ServeJit<'_>,
    stream: StreamId,
    independent: bool,
    parallelism: f64,
    device_backlog_us: Option<f64>,
) -> f64 {
    let group = 0u64;
    let depth = jit.window.pending_in_group(group);
    let cap = (jit.pack_cap(group) as u32).max(1);
    let queued = depth as u32 + 1;
    let mut est = if independent {
        let full = queued / cap;
        let rem = queued % cap;
        f64::from(full) * jit.executor().estimate_group_us(group, cap)
            + if rem > 0 {
                jit.executor().estimate_group_us(group, rem)
            } else {
                0.0
            }
    } else {
        let own = jit.window.stream_depth_in_group(stream, group) as u32 + 1;
        let launches = (jit.window.max_stream_depth_in_group(group) as u32)
            .max(own)
            .max(queued.div_ceil(cap));
        let per_launch = queued.div_ceil(launches).min(cap).max(1);
        f64::from(launches) * jit.executor().estimate_group_us(group, per_launch)
    };
    let parallelism = parallelism.max(1.0);
    est /= parallelism;
    est += match device_backlog_us {
        Some(backlog) => backlog,
        None => jit.inflight_group_est_us(group, parallelism.round() as u32) / parallelism,
    };
    est
}

fn wrap_view(gv: GroupView) -> AdmissionView {
    AdmissionView {
        seq: 1,
        now_us: 0.0,
        published: Instant::now(),
        groups: vec![gv],
        drained: vec![0],
        drained_by_stream: std::collections::BTreeMap::new(),
    }
}

#[test]
fn prop_admission_view_matches_sync_gate_on_identical_state() {
    // a snapshot published from some scheduler state must make the exact
    // decision the synchronous gate makes on that same state (no
    // in-channel backlog): same drain estimate, same accept/reject —
    // re-pinned PER SLO CLASS since the priority-surface refactor: each
    // probe carries a random class and both gates must route it through
    // the same class-aware decision (`Admission::decide_class`)
    let mut rng = Rng::new(0xF30A7);
    for case in 0..150 {
        let mut backend = SimBackend::default();
        let pack_cap = 1 + rng.below(16) as usize;
        let mut jit = serve_jit(&mut backend, pack_cap);
        // random window state: pending ops across up to 4 streams, some
        // randomly issued into in-flight launches
        let n = rng.below(12) as usize;
        for _ in 0..n {
            let stream = StreamId(rng.below(4) as u32);
            let req = DispatchRequest::new(stream, KernelDesc::gemm(1, 4, 1), 1e9)
                .with_group(0)
                .with_independent(rng.below(2) == 0);
            let _ = jit.submit_with(req, vec![0.0; 4]);
        }
        if rng.below(2) == 0 {
            let _ = jit.issue_ready();
        }
        let parallelism = 1.0 + rng.below(3) as f64;
        let backlog = if rng.below(2) == 0 {
            Some(rng.below(3_000) as f64)
        } else {
            None
        };
        let admission = Admission::new(1 + rng.below(16) as usize);
        let gview = snapshot_group(&jit, 0, parallelism, backlog, true);
        for probe in 0..9 {
            // re-stamp `published` per probe: the best-effort stale shed
            // is wall-clock gated, and a runner preemption mid-case must
            // not turn this equivalence check flaky
            let view = wrap_view(gview.clone());
            let stream = StreamId(rng.below(4) as u32);
            let independent = rng.below(2) == 0;
            let deadline_us = rng.below(6_000) as f64;
            let class = SloClass::from_index(rng.below(3) as usize);
            // the synchronous gate's decision, via the independently
            // written reference arithmetic
            let ref_est =
                reference_drain_est(&jit, stream, independent, parallelism, backlog);
            let sync = admission.decide_class(
                class,
                jit.window.pending_in_group(0),
                jit.window.inflight_in_group(0),
                deadline_us - jit.now_us - ref_est,
            );
            // the view-based estimate must agree to float precision
            let view_est = gview.drain_est_us(stream, independent, GateExtras::default());
            assert!(
                (view_est - ref_est).abs() < 1e-6,
                "case {case}.{probe}: view est {view_est} != reference {ref_est}"
            );
            // and a fresh frontend gate on the published view decides
            // identically (fresh = no accepted-in-channel backlog, view
            // just published so the best-effort stale shed cannot fire)
            let mut gate = FrontendGate::new(admission.clone(), 1);
            let greq = GateRequest {
                stream,
                independent,
                deadline_us,
                class,
            };
            let frontend = gate.decide(&view, 0, &greq, jit.now_us);
            assert_eq!(
                frontend, sync,
                "case {case}.{probe}: frontend {frontend:?} != sync {sync:?} \
                 (class {class:?}, est {ref_est}, deadline {deadline_us})"
            );
        }
    }
}

#[test]
fn prop_stale_view_never_over_admits() {
    // however stale the published snapshot, the frontend's own accept
    // counters bound outstanding work at max_queue — staleness may only
    // shed extra, never over-admit
    let mut rng = Rng::new(0xBEE51);
    for case in 0..120 {
        let max_queue = 1 + rng.below(12) as usize;
        let pending = rng.below(max_queue as u64 + 2) as usize;
        let inflight = rng.below(4) as usize;
        let gv = GroupView {
            pending,
            inflight,
            pack_cap: 4,
            est_by_n: vec![100.0, 150.0, 200.0, 250.0],
            inflight_est_us: rng.below(500) as f64,
            parallelism: 1.0,
            device_backlog_us: None,
            stream_depths: Vec::new(),
        };
        let view = wrap_view(gv);
        let mut gate = FrontendGate::new(Admission::new(max_queue), 1);
        let mut accepts = 0usize;
        // the view never refreshes while 3×max_queue requests arrive
        for i in 0..(max_queue * 3) {
            let stream = gate.intern(i as u32, 0);
            let greq = GateRequest {
                stream,
                independent: rng.below(2) == 0,
                deadline_us: 1e9,
                class: SloClass::Standard,
            };
            if gate.decide(&view, 0, &greq, 0.0) == Admit::Accept {
                accepts += 1;
            }
        }
        assert!(
            pending + inflight + accepts <= max_queue,
            "case {case}: {pending} pending + {inflight} inflight + {accepts} \
             accepted breaches max_queue {max_queue}"
        );
        // with room below the bound, generous deadlines are not shed
        if pending + inflight < max_queue {
            assert_eq!(
                accepts,
                max_queue - pending - inflight,
                "case {case}: staleness shed more than the bound requires"
            );
        }
    }
}

#[test]
fn prop_gate_reconciliation_tracks_scheduler_drains() {
    // accepted requests temporarily inflate the gate's effective depth;
    // once the scheduler reports them drained (and the window drained
    // them onward), capacity returns — over many random publish cycles
    // the gate's accepted-minus-drained bookkeeping never goes negative
    // and never lets outstanding exceed max_queue
    let mut rng = Rng::new(0xD2A1);
    for _case in 0..100 {
        let max_queue = 2 + rng.below(10) as usize;
        let mut gate = FrontendGate::new(Admission::new(max_queue), 1);
        let mut accepted_total = 0u64;
        let mut drained_total = 0u64;
        let mut completed_total = 0u64;
        for round in 0..20 {
            // scheduler publishes: everything drained so far that hasn't
            // completed is pending in the window
            let pending = (drained_total - completed_total) as usize;
            let gv = GroupView {
                pending,
                inflight: 0,
                pack_cap: 4,
                est_by_n: vec![100.0, 150.0, 200.0, 250.0],
                inflight_est_us: 0.0,
                parallelism: 1.0,
                device_backlog_us: None,
                stream_depths: Vec::new(),
            };
            let mut view = wrap_view(gv);
            view.seq = round;
            view.drained = vec![drained_total];
            // a burst of arrivals against this one view
            for i in 0..rng.below(8) {
                let stream = gate.intern((round * 100 + i) as u32, 0);
                let greq = GateRequest {
                    stream,
                    independent: true,
                    deadline_us: 1e9,
                    class: SloClass::Standard,
                };
                if gate.decide(&view, 0, &greq, 0.0) == Admit::Accept {
                    accepted_total += 1;
                }
            }
            let outstanding = pending as u64 + (accepted_total - drained_total);
            assert!(
                outstanding <= max_queue as u64,
                "round {round}: outstanding {outstanding} > max_queue {max_queue}"
            );
            // the scheduler drains some accepted requests and completes
            // some window work before the next publish
            let in_channel = accepted_total - drained_total;
            drained_total += rng.below(in_channel + 1);
            let queued = drained_total - completed_total;
            completed_total += rng.below(queued + 1);
        }
    }
}

// ---------------------------------------------------------------------------
// Unified-engine properties
// ---------------------------------------------------------------------------

use vliw_jit::serve::{BatchPolicy, Server};
use vliw_jit::workload::trace::{ArrivalKind, TenantSpec, Trace};

#[test]
fn prop_replay_and_replay_placed_agree_on_single_v100() {
    // the cross-mode equivalence pin: `replay` (the virtual ×
    // single-worker cell) and `replay_placed` on a one-v100 homogeneous
    // topology with no rebalance are THE SAME computation through the
    // unified engine — identical completions, drops, attainment, and
    // bit-identical spans, for randomized workload shapes. Only the
    // per-device metrics differ (replay reports none by contract).
    let mut rng = Rng::new(0x0E9A17);
    let topo = DeviceTopology::homogeneous(1, DeviceSpec::v100());
    for case in 0..10u64 {
        let n_tenants = 1 + rng.below(6) as u32;
        let models = ["a", "b"];
        let tenants: Vec<TenantSpec> = (0..n_tenants)
            .map(|i| {
                TenantSpec::new(
                    i,
                    models[i as usize % models.len()],
                    5_000 + rng.below(200_000),
                    50.0 + rng.f64() * 400.0,
                    if rng.below(2) == 0 {
                        ArrivalKind::Poisson
                    } else {
                        ArrivalKind::Bursty
                    },
                )
            })
            .collect();
        let per = 15 + rng.below(40) as usize;
        let trace = Trace::generate(&tenants, per, 1_000 + case);

        let mut plain = Server::new(SimBackend::default(), BatchPolicy::coalescing());
        let r1 = plain.replay(&trace);
        let mut placed = Server::new(SimBackend::default(), BatchPolicy::coalescing());
        let (r2, table) = placed.replay_placed(&trace, &topo, None);

        assert_eq!(
            r1.metrics.total_completed(),
            r2.metrics.total_completed(),
            "case {case}: completions diverge"
        );
        assert_eq!(r1.metrics.batches, r2.metrics.batches, "case {case}");
        assert_eq!(r1.metrics.useful_rows, r2.metrics.useful_rows, "case {case}");
        assert_eq!(
            r1.metrics.span_us.to_bits(),
            r2.metrics.span_us.to_bits(),
            "case {case}: spans diverge"
        );
        assert_eq!(
            r1.metrics.overall_attainment().to_bits(),
            r2.metrics.overall_attainment().to_bits(),
            "case {case}: attainment diverges"
        );
        assert_eq!(r1.metrics.jit.launches, r2.metrics.jit.launches, "case {case}");
        for ((ta_id, ta), (tb_id, tb)) in
            r1.metrics.tenants.iter().zip(r2.metrics.tenants.iter())
        {
            assert_eq!(ta_id, tb_id, "case {case}");
            assert_eq!(ta.slo_hits, tb.slo_hits, "case {case} tenant {ta_id}");
            assert_eq!(ta.slo_misses, tb.slo_misses, "case {case} tenant {ta_id}");
            assert_eq!(ta.dropped, tb.dropped, "case {case} tenant {ta_id}");
            assert_eq!(
                ta.latency.quantile_us(0.99).to_bits(),
                tb.latency.quantile_us(0.99).to_bits(),
                "case {case} tenant {ta_id}: latency distributions diverge"
            );
        }
        // the contract's asymmetry: only the placed mode reports devices
        assert!(r1.metrics.devices.is_empty(), "case {case}");
        assert_eq!(r2.metrics.devices.len(), 1, "case {case}");
        assert!(table.is_total(models.len() as u64, 1), "case {case}");
    }
}

// ---------------------------------------------------------------------------
// SLO-class properties
// ---------------------------------------------------------------------------

/// Replay `trace` through the virtual serving cell and return the metrics.
fn replay_metrics(trace: &Trace) -> vliw_jit::serve::metrics::ServeMetrics {
    let mut s = Server::new(SimBackend::default(), BatchPolicy::coalescing());
    s.replay(trace).metrics
}

#[test]
fn prop_critical_attainment_monotone_under_best_effort_load() {
    // the tentpole's protection guarantee: piling best-effort load onto a
    // non-saturated cell must not degrade critical attainment. Tenant
    // arrival streams are derived from `seed ^ tenant_id`, so stacking
    // extra best-effort tenants leaves the critical arrivals bit-identical
    // — any attainment change is purely a scheduling effect.
    let mut rng = Rng::new(0x510C1A);
    for case in 0..6u64 {
        let crit_rate = 100.0 + rng.f64() * 150.0;
        let std_rate = 100.0 + rng.f64() * 100.0;
        let base = vec![
            TenantSpec::new(0, "m", 30_000, crit_rate, ArrivalKind::Poisson)
                .with_class(SloClass::Critical),
            TenantSpec::new(1, "m", 30_000, crit_rate, ArrivalKind::Poisson)
                .with_class(SloClass::Critical),
            TenantSpec::new(2, "m", 100_000, std_rate, ArrivalKind::Poisson)
                .with_class(SloClass::Standard),
        ];
        let seed = 9_000 + case;
        let run = |extra_be: u32| {
            let mut tenants = base.clone();
            for j in 0..extra_be {
                tenants.push(
                    TenantSpec::new(10 + j, "m", 2_000_000, 1_000.0, ArrivalKind::Poisson)
                        .with_class(SloClass::BestEffort),
                );
            }
            replay_metrics(&Trace::generate(&tenants, 60, seed))
                .class_attainment(SloClass::Critical)
        };
        let quiet = run(0);
        for extra in [2u32, 6] {
            let loaded = run(extra);
            assert!(
                loaded >= quiet - 0.05,
                "case {case}: critical attainment fell from {quiet} to {loaded} \
                 under {extra} best-effort tenants (crit_rate {crit_rate:.0}/s)"
            );
        }
    }
}

#[test]
fn prop_best_effort_starvation_is_bounded() {
    // the flip side of priority: class weighting is work-conserving, not a
    // strict-priority starver. On a cell with capacity to spare after the
    // critical load is served, best-effort traffic must still complete a
    // substantial fraction of its offered work, and per-class accounting
    // must conserve requests (completed + dropped == offered).
    let mut rng = Rng::new(0xBE57A3);
    for case in 0..6u64 {
        let crit_rate = 800.0 + rng.f64() * 800.0;
        let tenants = vec![
            TenantSpec::new(0, "m", 50_000, crit_rate, ArrivalKind::Poisson)
                .with_class(SloClass::Critical),
            TenantSpec::new(1, "m", 50_000, crit_rate, ArrivalKind::Poisson)
                .with_class(SloClass::Critical),
            TenantSpec::new(2, "m", 2_000_000, 400.0, ArrivalKind::Poisson)
                .with_class(SloClass::BestEffort),
            TenantSpec::new(3, "m", 2_000_000, 400.0, ArrivalKind::Poisson)
                .with_class(SloClass::BestEffort),
        ];
        let trace = Trace::generate(&tenants, 80, 4_200 + case);
        let m = replay_metrics(&trace);

        let offered_be: u64 = [2u32, 3]
            .iter()
            .map(|t| trace.of_tenant(*t).count() as u64)
            .sum();
        let be = m.class_metrics(SloClass::BestEffort);
        assert_eq!(
            be.completed() + be.dropped,
            offered_be,
            "case {case}: best-effort accounting leaks requests"
        );
        assert!(be.completed() > 0, "case {case}: best-effort fully starved");
        assert!(
            be.completed() as f64 >= 0.5 * offered_be as f64,
            "case {case}: best-effort starved beyond bound: {} of {offered_be} \
             completed (crit_rate {crit_rate:.0}/s)",
            be.completed()
        );
        assert!(
            m.class_attainment(SloClass::Critical) >= 0.9,
            "case {case}: critical attainment collapsed to {}",
            m.class_attainment(SloClass::Critical)
        );
    }
}

// ---------------------------------------------------------------------------
// Analysis-pass mutation properties: each seeded mutation (a hazard the
// scheduler/coalescer must never construct, or a log a correct run can
// never emit) must be flagged with exactly its catalog rule id — see the
// rule tables in the `vliw_jit::analysis` module docs
// ---------------------------------------------------------------------------

use std::sync::Arc;

use vliw_jit::analysis::audit::{audit_lines, audit_path, events, AuditLog};
use vliw_jit::analysis::lint::lint_tree;
use vliw_jit::analysis::plan::{only_rule, rule_ids, verify_pack};
use vliw_jit::compiler::coalescer::SuperKernel;
use vliw_jit::util::json::Json;
use vliw_jit::workload::trace::mixed_tenants;

fn plan_req(stream: u32) -> DispatchRequest {
    DispatchRequest::new(StreamId(stream), KernelDesc::gemm(1, 256, 256), 10_000.0)
}

/// Hand-build the pack a mutated coalescer would emit: `shape` names the
/// pack's class, `ids` its members (legality deliberately unchecked).
fn pack_of(ids: Vec<OpId>, shape: &KernelDesc) -> SuperKernel {
    let class = ShapeClass::of(shape);
    let problems = ids.len() as u32;
    SuperKernel {
        class,
        ops: ids,
        useful_flops: 1.0,
        kernel: class.kernel(problems),
    }
}

#[test]
fn mutation_plan_catches_requeue_order_bug() {
    // replay the PR 2 straggler-eviction state: seq 0 of a dependent
    // stream issues, seq 1 becomes ready, then seq 0 is evicted back to
    // pending. A mutated scheduler that still issues seq 1 (the old
    // requeue-order bug: the requeued op re-entered at the BACK of the
    // stream queue) must trip PLAN001.
    let mut w = Window::new(64);
    let a = w.submit(plan_req(0), 0.0).expect("capacity");
    let b = w.submit(plan_req(0), 0.0).expect("capacity");
    w.issue(&[a]);
    assert!(w.ready().iter().any(|o| o.id == b), "seq 1 ready after seq 0 issues");
    w.requeue(a);
    let vs = verify_pack(
        &w,
        &Coalescer::default(),
        &pack_of(vec![b], &KernelDesc::gemm(1, 256, 256)),
        &[],
    );
    assert!(
        rule_ids(&vs).contains(&"PLAN001"),
        "requeue-order mutation not flagged as PLAN001: {vs:?}"
    );
}

#[test]
fn mutation_plan_flags_cross_group_pack() {
    let mut w = Window::new(64);
    let a = w.submit(plan_req(0).with_group(0), 0.0).expect("capacity");
    let b = w.submit(plan_req(1).with_group(1), 0.0).expect("capacity");
    let vs = verify_pack(
        &w,
        &Coalescer::default(),
        &pack_of(vec![a, b], &KernelDesc::gemm(1, 256, 256)),
        &[],
    );
    assert!(only_rule(&vs, "PLAN002"), "{vs:?}");
}

#[test]
fn mutation_plan_flags_merged_classes() {
    let mut w = Window::new(64);
    let a = w
        .submit(plan_req(0).with_class(SloClass::Critical), 0.0)
        .expect("capacity");
    let b = w
        .submit(plan_req(1).with_class(SloClass::BestEffort), 0.0)
        .expect("capacity");
    let vs = verify_pack(
        &w,
        &Coalescer::default(),
        &pack_of(vec![a, b], &KernelDesc::gemm(1, 256, 256)),
        &[],
    );
    assert!(only_rule(&vs, "PLAN003"), "{vs:?}");
}

#[test]
fn mutation_plan_flags_shape_mix() {
    // 100x256x256 quantizes to a different power-of-two class than
    // 1x256x256 and is not the pack class's exact dims either
    let mut w = Window::new(64);
    let a = w.submit(plan_req(0), 0.0).expect("capacity");
    let b = w
        .submit(
            DispatchRequest::new(StreamId(1), KernelDesc::gemm(100, 256, 256), 10_000.0),
            0.0,
        )
        .expect("capacity");
    let vs = verify_pack(
        &w,
        &Coalescer::default(),
        &pack_of(vec![a, b], &KernelDesc::gemm(1, 256, 256)),
        &[],
    );
    assert!(only_rule(&vs, "PLAN004"), "{vs:?}");
}

#[test]
fn mutation_plan_flags_cap_overflow() {
    let mut w = Window::new(64);
    let ids: Vec<OpId> = (0..3)
        .map(|s| w.submit(plan_req(s), 0.0).expect("capacity"))
        .collect();
    let vs = verify_pack(
        &w,
        &Coalescer::new(2, 1.0),
        &pack_of(ids, &KernelDesc::gemm(1, 256, 256)),
        &[],
    );
    assert!(only_rule(&vs, "PLAN005"), "{vs:?}");
}

#[test]
fn mutation_plan_flags_unready_issue() {
    let mut w = Window::new(64);
    let a = w.submit(plan_req(0), 0.0).expect("capacity");
    w.issue(&[a]); // InFlight, not Ready
    let vs = verify_pack(
        &w,
        &Coalescer::default(),
        &pack_of(vec![a], &KernelDesc::gemm(1, 256, 256)),
        &[],
    );
    assert!(only_rule(&vs, "PLAN006"), "{vs:?}");
}

#[test]
fn mutation_plan_flags_double_issue() {
    let mut w = Window::new(64);
    let a = w.submit(plan_req(0), 0.0).expect("capacity");
    let b = w.submit(plan_req(1), 0.0).expect("capacity");
    let live = pack_of(vec![a, b], &KernelDesc::gemm(1, 256, 256));
    w.issue(&live.ops);
    // replaying a live ticket's plan: every member trips PLAN007
    // (already live) and PLAN006 (InFlight, not Ready), nothing else
    let vs = verify_pack(&w, &Coalescer::default(), &live, &[&live]);
    assert_eq!(rule_ids(&vs), vec!["PLAN006", "PLAN007"], "{vs:?}");
}

fn log_text(events: Vec<Json>) -> String {
    events
        .iter()
        .map(|e| e.to_string_compact())
        .collect::<Vec<_>>()
        .join("\n")
}

fn audit_rules(text: &str) -> Vec<&'static str> {
    rule_ids(&audit_lines(text).expect("well-formed log").violations)
}

#[test]
fn mutation_audit_flags_seq_swap() {
    // a dependent op's launch precedes its predecessor's: the ordering
    // hazard the window exists to prevent, visible in the log alone
    let text = log_text(vec![events::launch(1, 0, "standard", 8, &[(0, 1, false)])]);
    assert_eq!(audit_rules(&text), vec!["AUDIT001"]);
    // the same launch order is legal for an independent op
    let ok = log_text(vec![events::launch(1, 0, "standard", 8, &[(0, 1, true)])]);
    assert_eq!(audit_rules(&ok), Vec::<&str>::new());
}

#[test]
fn mutation_audit_catches_stale_view_overadmit() {
    // the PR 6 stale-view bug class: an admission gate deciding on a
    // stale snapshot books queued + inflight past the bound it priced
    // under — exactly what a correct gate's own accept counters prevent
    // (prop_stale_view_never_over_admits) and what the auditor must
    // flag if a regression ever re-introduces it
    let text = log_text(vec![events::admit(0, 0, "standard", 5, 2, 6)]);
    assert_eq!(audit_rules(&text), vec!["AUDIT002"]);
    let ok = log_text(vec![events::admit(0, 0, "standard", 4, 2, 6)]);
    assert_eq!(audit_rules(&ok), Vec::<&str>::new());
}

#[test]
fn mutation_audit_flags_totality_break() {
    // a rebalance snapshot with a 0-replica group (routing black hole),
    // then one whose group set changed (groups are workload identity,
    // not placement state)
    let text = log_text(vec![events::rebalance(1, &[(0, 1), (1, 0)])]);
    assert_eq!(audit_rules(&text), vec!["AUDIT003"]);
    let drift = log_text(vec![
        events::rebalance(1, &[(0, 1), (1, 1)]),
        events::rebalance(2, &[(0, 2)]),
    ]);
    assert_eq!(audit_rules(&drift), vec!["AUDIT003"]);
}

#[test]
fn mutation_audit_flags_duplicate_reply() {
    let token = (5u64 << 16) | 1;
    let twice = log_text(vec![
        events::complete(0, 0, 0, 100.0, 200.0, true, false, token),
        events::reply(token),
        events::reply(token),
    ]);
    assert_eq!(audit_rules(&twice), vec!["AUDIT004"]);
    // ...and a completed wire op whose reply never happened and whose
    // batch was never purged is the other half of the totality rule
    let never = log_text(vec![events::complete(0, 0, 0, 100.0, 200.0, true, false, token)]);
    assert_eq!(audit_rules(&never), vec!["AUDIT004"]);
    // a disconnect purge legitimately absorbs the missing reply
    let purged = log_text(vec![
        events::complete(0, 0, 0, 100.0, 200.0, true, false, token),
        events::purge(3, &[5]),
    ]);
    assert_eq!(audit_rules(&purged), Vec::<&str>::new());
}

#[test]
fn mutation_audit_flags_met_mismatch() {
    // met=true past the deadline: the accounting lie SLO attainment
    // would silently inherit
    let text = log_text(vec![events::complete(0, 0, 0, 300.0, 200.0, true, false, 0)]);
    assert_eq!(audit_rules(&text), vec!["AUDIT005"]);
    // failed runs may never count as met either
    let failed = log_text(vec![events::complete(0, 0, 0, 100.0, 200.0, true, true, 0)]);
    assert_eq!(audit_rules(&failed), vec!["AUDIT005"]);
}

#[test]
fn audit_clean_on_real_replay_log() {
    // end to end: a deterministic virtual-time replay with the launch
    // log attached (and, in debug builds, the plan verifier live at
    // every issue) must produce a log the auditor passes untouched
    let path = std::env::temp_dir().join(format!("vliw_audit_{}.jsonl", std::process::id()));
    {
        let mut server = Server::new(SimBackend::default(), BatchPolicy::coalescing());
        server.launch_log = Some(Arc::new(AuditLog::create(&path).expect("create log")));
        let tenants = mixed_tenants(4, &["simnet"], 300.0);
        let trace = Trace::generate(&tenants, 40, 42);
        let report = server.replay(&trace);
        assert!(report.metrics.total_completed() > 0);
        assert_eq!(report.metrics.jit.plan_violations, 0);
    }
    let report = audit_path(&path).expect("readable log");
    let _ = std::fs::remove_file(&path);
    assert!(report.events > 0 && report.launches > 0 && report.admissions > 0);
    assert!(
        report.violations.is_empty(),
        "clean replay flagged: {:?}",
        report.violations
    );
}

#[test]
fn lint_tree_is_clean_on_this_source() {
    // integration tests run from the crate root, so `rust/src` is the
    // tree `vliwd lint` defends in CI — it must hold its own rules
    let report = lint_tree("rust/src").expect("scan rust/src");
    assert!(report.files > 20, "scanned only {} files", report.files);
    assert!(report.violations.is_empty(), "{:?}", report.violations);
}
