//! Integration tests across the full stack: manifest → PJRT → JIT →
//! serving, on the real compiled artifacts (requires `make artifacts`).

use vliw_jit::compiler::ir::{DispatchRequest, StreamId};
use vliw_jit::compiler::jit::{JitCompiler, JitConfig};
use vliw_jit::gpu::kernel::KernelDesc;
use vliw_jit::runtime::{Manifest, PjrtExecutor};
use vliw_jit::serve::{BatchPolicy, Server};
use vliw_jit::workload::trace::{ArrivalKind, Request, TenantSpec, Trace};

fn executor() -> PjrtExecutor {
    PjrtExecutor::from_default_artifacts().expect("make artifacts first")
}

#[test]
fn every_artifact_golden_checks() {
    // The strongest numeric statement in the repo: every compiled model
    // variant and every superkernel matches the python jnp reference.
    let mut ex = executor();
    let models: Vec<(String, Vec<u32>)> = ex
        .manifest()
        .models
        .values()
        .map(|e| (e.name.clone(), e.artifacts.iter().map(|a| a.batch).collect()))
        .collect();
    for (model, batches) in models {
        for b in batches {
            let err = ex
                .golden_check_model(&model, b)
                .unwrap_or_else(|e| panic!("{model} b{b}: {e}"));
            assert!(err < 2e-3, "{model} b{b}: rel err {err}");
        }
    }
    let supers = ex.manifest().supers.clone();
    for s in supers {
        let err = ex
            .golden_check_super(&s)
            .unwrap_or_else(|e| panic!("super_{}_p{}: {e}", s.class, s.problems));
        assert!(err < 1e-3, "super_{}_p{}: {err}", s.class, s.problems);
    }
}

#[test]
fn jit_coalesces_mixed_classes_on_real_artifacts() {
    // streams issue a mix of class-A and class-B shapes; the JIT must form
    // one superkernel per class and execute both on real artifacts
    let mut jit = JitCompiler::new(JitConfig::default(), executor());
    let mut ops = Vec::new();
    for s in 0..3u32 {
        ops.push((
            0.0,
            DispatchRequest::new(StreamId(s), KernelDesc::gemm(32, 256, 256), 1e7),
        ));
    }
    for s in 3..6u32 {
        ops.push((
            0.0,
            DispatchRequest::new(StreamId(s), KernelDesc::gemm(32, 512, 512), 1e7),
        ));
    }
    let done = jit.run_trace(ops);
    assert_eq!(done.len(), 6);
    assert_eq!(jit.stats.launches, 2, "one superkernel per shape class");
    assert_eq!(jit.executor().executions, 2);
    assert!(done.iter().all(|c| c.pack_size == 3));
    assert_eq!(jit.stats.slo_attainment(), 1.0);
}

#[test]
fn jit_respects_slo_priority_on_real_artifacts() {
    let mut jit = JitCompiler::new(JitConfig::default(), executor());
    let done = jit.run_trace(vec![
        (
            0.0,
            DispatchRequest::new(StreamId(0), KernelDesc::gemm(64, 1024, 1024), 1e8)
                .with_tag(1),
        ),
        (
            0.0,
            DispatchRequest::new(StreamId(1), KernelDesc::gemm(32, 256, 256), 40_000.0)
                .with_tag(2),
        ),
    ]);
    let tight = done.iter().find(|c| c.op.tag == 2).unwrap();
    let big = done.iter().find(|c| c.op.tag == 1).unwrap();
    assert!(tight.issue_us <= big.issue_us, "EDF must win");
    assert!(tight.met_deadline);
}

#[test]
fn serve_replay_accounts_every_request() {
    let tenants = vec![
        TenantSpec::new(0, "mlp_small", 50_000, 300.0, ArrivalKind::Poisson),
        TenantSpec::new(1, "mlp_small", 200_000, 200.0, ArrivalKind::Bursty),
        TenantSpec::new(2, "gemmnet6", 200_000, 100.0, ArrivalKind::Poisson),
    ];
    let trace = Trace::generate(&tenants, 30, 7);
    let mut server = Server::new(executor(), BatchPolicy::coalescing());
    let report = server.replay(&trace);
    let drops: u64 = report.metrics.tenants.values().map(|t| t.dropped).sum();
    assert_eq!(
        report.metrics.total_completed() + drops,
        90,
        "conservation: every request completes or is dropped"
    );
    assert!(report.metrics.batches > 0);
    assert!(report.metrics.mean_occupancy() >= 1.0);
    // batching must actually happen under concurrent load
    assert!(
        report.metrics.mean_occupancy() > 1.2,
        "occupancy {}",
        report.metrics.mean_occupancy()
    );
}

#[test]
fn single_tenant_burst_coalesces_on_real_artifacts() {
    // stream-prefix coalescing end to end: ONE tenant's burst of 8
    // independent requests rides multi-problem packs on the real compiled
    // batch variants instead of serializing into singleton launches
    let requests: Vec<Request> = (0..8)
        .map(|i| Request {
            id: i,
            tenant: 0,
            model: "mlp_small".to_string(),
            arrival_us: i as f64 * 100.0,
            deadline_us: i as f64 * 100.0 + 500_000.0,
        })
        .collect();
    let trace = Trace {
        requests,
        tenants: vec![TenantSpec::new(
            0,
            "mlp_small",
            500_000,
            10_000.0,
            ArrivalKind::Poisson,
        )],
    };
    let mut server = Server::new(executor(), BatchPolicy::coalescing());
    let report = server.replay(&trace);
    assert_eq!(report.metrics.total_completed(), 8);
    assert!(
        report.metrics.jit.mean_pack() > 1.5,
        "single-stream burst must coalesce, mean_pack {}",
        report.metrics.jit.mean_pack()
    );
    assert!(report.metrics.same_stream_rows > 0);
    assert_eq!(
        report.metrics.overall_attainment(),
        1.0,
        "generous SLOs all met"
    );
}

#[test]
fn serve_fifo_vs_coalescing_device_time() {
    let tenants = vec![
        TenantSpec::new(0, "mlp_small", 1_000_000, 400.0, ArrivalKind::Poisson),
        TenantSpec::new(1, "mlp_small", 1_000_000, 400.0, ArrivalKind::Poisson),
    ];
    let trace = Trace::generate(&tenants, 40, 3);
    let mut coal = Server::new(executor(), BatchPolicy::coalescing());
    let rc = coal.replay(&trace);
    let mut fifo = Server::new(executor(), BatchPolicy::NoBatching);
    let rf = fifo.replay(&trace);
    assert!(
        rc.metrics.busy_us < rf.metrics.busy_us,
        "coalescing {} µs must use less device time than fifo {} µs",
        rc.metrics.busy_us,
        rf.metrics.busy_us
    );
}

#[test]
fn manifest_round_trips_through_json_writer() {
    // parse → serialize → parse: structural identity
    let m = Manifest::load_default().expect("artifacts");
    let text = std::fs::read_to_string(m.dir.join("manifest.json")).unwrap();
    let j = vliw_jit::util::json::Json::parse(&text).unwrap();
    let again = vliw_jit::util::json::Json::parse(&j.to_string_compact()).unwrap();
    assert_eq!(j, again);
}

#[test]
fn backpressure_returns_none_not_panic() {
    let cfg = JitConfig {
        window_capacity: 2,
        ..JitConfig::default()
    };
    let mut jit = JitCompiler::new(cfg, vliw_jit::compiler::jit::SimExecutor::v100());
    assert!(jit
        .submit(DispatchRequest::new(
            StreamId(0),
            KernelDesc::gemm(8, 8, 8),
            1e6
        ))
        .is_some());
    assert!(jit
        .submit(DispatchRequest::new(
            StreamId(1),
            KernelDesc::gemm(8, 8, 8),
            1e6
        ))
        .is_some());
    assert!(jit
        .submit(DispatchRequest::new(
            StreamId(2),
            KernelDesc::gemm(8, 8, 8),
            1e6
        ))
        .is_none());
}
