//! Loopback end-to-end tests of the network intake subsystem: a real
//! TCP wire on 127.0.0.1:0, the simulator backend behind it (no compiled
//! artifacts needed), driven through the same client paths `vliwd
//! loadgen` uses. Covers the batch/reply contract, per-stream ordering
//! across intake shards, and bookkeeping under connection churn.

use std::net::TcpStream;
use std::time::Duration;

use vliw_jit::compiler::ir::SloClass;
use vliw_jit::serve::intake::loadgen::run_loadgen;
use vliw_jit::serve::intake::serve_wire;
use vliw_jit::serve::intake::wire::{
    decode_reply, encode_request, read_frame, write_frame, FrameKind, WireOp, WireRequest,
};
use vliw_jit::serve::{BatchPolicy, Server, SimBackend};
use vliw_jit::workload::trace::{ArrivalKind, TenantSpec};
use vliw_jit::workload::wire::TimedWireRequest;

/// A tenant with a 10-second SLO: generous enough that a loopback test
/// never sheds on staleness, so op outcomes are deterministic.
fn tenant(id: u32) -> TenantSpec {
    TenantSpec::new(id, "simnet", 10_000_000, 1_000.0, ArrivalKind::Poisson)
}

fn op(tenant: u32, seed: u64) -> WireOp {
    WireOp {
        tenant,
        model: "simnet".into(),
        slo_us: 10_000_000.0,
        class: SloClass::Standard,
        seed,
    }
}

#[test]
fn client_batch_gets_exactly_one_reply_after_all_members_complete() {
    let ws = serve_wire(
        || Server::new(SimBackend::default(), BatchPolicy::coalescing()),
        vec![tenant(0)],
        "127.0.0.1:0",
        2,
        None,
    )
    .expect("bind loopback");
    // one wire request carrying a client batch of 8 independent ops,
    // replayed through the loadgen client path
    let reqs = vec![TimedWireRequest {
        at_us: 0.0,
        tenant: 0,
        req: WireRequest {
            id: 77,
            ops: (0..8).map(|i| op(0, i)).collect(),
        },
    }];
    let rep = run_loadgen(ws.addr(), &reqs, 1).expect("loadgen");
    assert_eq!(rep.sent_batches, 1);
    assert_eq!(rep.sent_ops, 8);
    assert_eq!(rep.replies, 1, "a batch gets exactly ONE reply");
    assert_eq!(
        rep.ok_ops + rep.rejected_ops + rep.failed_ops,
        8,
        "the reply carries a terminal status for every member"
    );
    assert_eq!(rep.ok_ops, 8, "an unloaded loopback server completes all 8");
    assert_eq!(rep.timeouts, 0);
    assert_eq!(ws.pending_batches(), 0, "the batch retired from the table");
    let report = ws.shutdown();
    let intake = &report.metrics.intake;
    assert_eq!(intake.batch_sizes.get(&8), Some(&1));
    assert_eq!(intake.replies, 1);
    assert_eq!(intake.dropped_replies, 0);
    assert_eq!(report.metrics.total_completed(), 8);
}

#[test]
fn per_stream_order_holds_across_intake_shards_for_dependent_streams() {
    // Dependent streams: program order binds, so each tenant's requests
    // must complete — and reply — in send order. Two connections land on
    // two different intake shards (conn id % shards), each pipelining 20
    // single-op requests without waiting for replies.
    let ws = serve_wire(
        || {
            let mut s = Server::new(SimBackend::default(), BatchPolicy::coalescing());
            s.independent_streams = false;
            s
        },
        vec![tenant(0), tenant(1)],
        "127.0.0.1:0",
        2,
        None,
    )
    .expect("bind loopback");
    let addr = ws.addr();
    let n = 20u64;
    let handles: Vec<_> = (0..2u32)
        .map(|t| {
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).expect("connect");
                stream.set_nodelay(true).ok();
                for k in 0..n {
                    let req = WireRequest {
                        id: 1_000 * t as u64 + k,
                        ops: vec![op(t, k)],
                    };
                    write_frame(&mut stream, FrameKind::Request, &encode_request(&req))
                        .expect("send");
                }
                stream
                    .set_read_timeout(Some(Duration::from_secs(30)))
                    .expect("timeout");
                (0..n)
                    .map(|_| {
                        let f = read_frame(&mut stream).expect("reply frame");
                        assert_eq!(f.kind, FrameKind::Reply);
                        decode_reply(&f.payload).expect("reply payload").id
                    })
                    .collect::<Vec<u64>>()
            })
        })
        .collect();
    for (t, h) in handles.into_iter().enumerate() {
        let ids = h.join().expect("client thread");
        let expect: Vec<u64> = (0..n).map(|k| 1_000 * t as u64 + k).collect();
        assert_eq!(ids, expect, "conn {t}: replies out of send order");
    }
    ws.shutdown();
}

#[test]
fn stalled_reader_never_blocks_another_connections_replies() {
    // One intake shard, so both connections share every server-side
    // thread: reply isolation must come from the per-connection outbound
    // queues, not from shard separation. Connection A floods request
    // batches whose ops all name an unknown model — each op is rejected
    // at intake, so the reply frames head straight for A's outbound
    // queue with no engine latency in between. A never reads: its socket
    // buffer fills, and a writer that blocked (or retried in place)
    // on A's socket would stall every other connection's replies.
    let ws = serve_wire(
        || Server::new(SimBackend::default(), BatchPolicy::coalescing()),
        vec![tenant(0)],
        "127.0.0.1:0",
        1,
        None,
    )
    .expect("bind loopback");
    let addr = ws.addr();
    let mut a = TcpStream::connect(addr).expect("connect A");
    a.set_nodelay(true).ok();
    for k in 0..500u64 {
        let req = WireRequest {
            id: k,
            ops: (0..64)
                .map(|i| WireOp {
                    tenant: 0,
                    model: "no_such_model".into(),
                    slo_us: 10_000_000.0,
                    class: SloClass::Standard,
                    seed: k * 64 + i,
                })
                .collect(),
        };
        write_frame(&mut a, FrameKind::Request, &encode_request(&req)).expect("A send");
    }
    // connection B: one real request. Its reply must arrive promptly
    // even though A has hundreds of replies jammed ahead of it.
    let mut b = TcpStream::connect(addr).expect("connect B");
    b.set_nodelay(true).ok();
    let req = WireRequest {
        id: 9_999,
        ops: vec![op(0, 1)],
    };
    write_frame(&mut b, FrameKind::Request, &encode_request(&req)).expect("B send");
    b.set_read_timeout(Some(Duration::from_secs(30)))
        .expect("timeout");
    let f = read_frame(&mut b).expect("B's reply must not wait on A's stalled socket");
    assert_eq!(f.kind, FrameKind::Reply);
    let reply = decode_reply(&f.payload).expect("reply payload");
    assert_eq!(reply.id, 9_999);
    assert_eq!(reply.ops.len(), 1);
    drop(a);
    drop(b);
    ws.shutdown();
}

#[test]
fn mid_flight_disconnect_drops_pending_replies_without_leaking() {
    // Connection churn: clients fire a 2-op batch and vanish without
    // reading the reply. Whatever path each batch takes — reply written
    // into a closing socket, reply write failing, or the batch purged at
    // disconnect before its ops complete — the reply table must drain to
    // empty and the disconnects must all be counted.
    let ws = serve_wire(
        || Server::new(SimBackend::default(), BatchPolicy::coalescing()),
        vec![tenant(0)],
        "127.0.0.1:0",
        2,
        None,
    )
    .expect("bind loopback");
    let addr = ws.addr();
    let cycles = 30u64;
    for c in 0..cycles {
        let mut stream = TcpStream::connect(addr).expect("connect");
        let req = WireRequest {
            id: c,
            ops: (0..2).map(|i| op(0, c * 2 + i)).collect(),
        };
        write_frame(&mut stream, FrameKind::Request, &encode_request(&req)).expect("send");
        drop(stream); // mid-flight disconnect: nobody reads the reply
    }
    let mut pending = ws.pending_batches();
    for _ in 0..200 {
        if pending == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
        pending = ws.pending_batches();
    }
    assert_eq!(pending, 0, "reply table leaked batches under churn");
    let report = ws.shutdown();
    let intake = &report.metrics.intake;
    assert!(
        intake.connections >= cycles,
        "adopted {} of {cycles} connections",
        intake.connections
    );
    assert!(
        intake.disconnects >= cycles,
        "counted {} of {cycles} disconnects",
        intake.disconnects
    );
    // every batch reached exactly one terminal accounting state
    assert!(
        intake.replies + intake.dropped_replies <= cycles,
        "replies {} + dropped {} over {cycles} batches",
        intake.replies,
        intake.dropped_replies
    );
}
