"""Property-based sweeps (hypothesis) over the Pallas kernels.

Randomized shapes / dtypes / tile configs, always asserted against the
pure-jnp oracle. Deadlines disabled: interpret-mode pallas is slow and
single-core.
"""

import numpy as np
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from compile import model as M
from compile.kernels import BlockConfig, coalesced_matmul, fused_linear, resolve_tiles
from compile.kernels import ref as R

SETTINGS = settings(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


def _arr(shape, base):
    return jnp.asarray(
        M.hash01(np.arange(int(np.prod(shape))), base=base).reshape(shape)
    )


dims = st.sampled_from([1, 2, 4, 8, 16, 24, 32, 48, 64, 96, 128])
kdims = st.sampled_from([8, 16, 32, 64, 128, 256, 384])
tiles = st.sampled_from([4, 8, 16, 32, 64, 128])


@SETTINGS
@given(
    p=st.integers(1, 6),
    m=dims,
    k=kdims,
    n=dims,
    tm=tiles,
    tn=tiles,
    tk=tiles,
    base=st.integers(0, 1 << 16),
)
def test_coalesced_matmul_matches_ref(p, m, k, n, tm, tn, tk, base):
    a = _arr((p, m, k), base)
    b = _arr((p, k, n), base + 7919)
    cfg = BlockConfig(tm=tm, tn=tn, tk=tk)
    out = coalesced_matmul(a, b, config=cfg)
    np.testing.assert_allclose(out, R.coalesced_matmul_ref(a, b), rtol=2e-4, atol=2e-4)


@SETTINGS
@given(
    m=dims,
    k=kdims,
    n=dims,
    act=st.sampled_from(["none", "relu", "gelu"]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    base=st.integers(0, 1 << 16),
)
def test_fused_linear_matches_ref(m, k, n, act, dtype, base):
    dt = jnp.bfloat16 if dtype == "bfloat16" else jnp.float32
    x = _arr((m, k), base).astype(dt)
    w = _arr((k, n), base + 13).astype(dt)
    b = _arr((n,), base + 29).astype(dt)
    out = fused_linear(x, w, b, act=act)
    tol = 5e-2 if dtype == "bfloat16" else 1e-4
    np.testing.assert_allclose(
        out, R.fused_linear_ref(x, w, b, act=act), rtol=tol, atol=tol
    )


@SETTINGS
@given(
    m=st.integers(1, 300),
    n=st.integers(1, 300),
    k=st.integers(1, 600),
    tm=st.integers(1, 256),
    tn=st.integers(1, 256),
    tk=st.integers(1, 1024),
)
def test_resolve_tiles_always_divides(m, n, k, tm, tn, tk):
    cfg = resolve_tiles(m, n, k, BlockConfig(tm=tm, tn=tn, tk=tk))
    assert m % cfg.tm == 0 and n % cfg.tn == 0 and k % cfg.tk == 0
    assert 1 <= cfg.tm <= m and 1 <= cfg.tn <= n and 1 <= cfg.tk <= k


@SETTINGS
@given(p=st.integers(2, 6), m=dims, k=kdims, n=dims, base=st.integers(0, 1 << 16))
def test_packing_independence_property(p, m, k, n, base):
    """For random packs, each problem's slice equals its solo computation —
    the invariant the VLIW coalescer relies on."""
    a = _arr((p, m, k), base)
    b = _arr((p, k, n), base + 101)
    packed = coalesced_matmul(a, b)
    i = base % p
    solo = coalesced_matmul(a[i : i + 1], b[i : i + 1])
    np.testing.assert_allclose(packed[i], solo[0], rtol=1e-6, atol=1e-6)
