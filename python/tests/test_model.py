"""L2 model tests: forward-vs-reference, shapes, determinism, and the
cross-language hash01 golden values the rust side pins too."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import model as M
from compile.kernels import ref as R

# ---------------------------------------------------------------------------
# hash01 / fnv1a: these exact literals are also asserted by
# rust/src/runtime/golden.rs — they pin the cross-language contract.
# ---------------------------------------------------------------------------

HASH01_FIRST6 = [0.195082441, 0.706475973, -0.552727699, -0.869781792, -0.42700702, 0.493466735]
HASH01_BASE1M_FIRST3 = [-0.365425706, -0.783480048, -0.861492336]


def test_hash01_golden_values():
    np.testing.assert_allclose(M.hash01(np.arange(6)), HASH01_FIRST6, rtol=1e-6)
    np.testing.assert_allclose(
        M.hash01(np.arange(3), base=1 << 20), HASH01_BASE1M_FIRST3, rtol=1e-6
    )


def test_hash01_range_and_spread():
    v = M.hash01(np.arange(100_000))
    assert v.min() >= -1.0 and v.max() < 1.0
    assert abs(float(v.mean())) < 0.01  # roughly centered
    assert 0.5 < float(v.std()) < 0.65  # roughly uniform (std ~ 1/sqrt(3))


def test_fnv1a_golden():
    assert M.fnv1a("mlp_small.w0") == 1396747245


def test_gen_weight_deterministic_and_scaled():
    w1 = M.gen_weight("mlp_small.w0", (256, 256), 256)
    w2 = M.gen_weight("mlp_small.w0", (256, 256), 256)
    np.testing.assert_array_equal(w1, w2)
    assert w1.reshape(-1)[0] == pytest.approx(0.0784961134, rel=1e-6)
    # different tensor name -> different stream
    w3 = M.gen_weight("mlp_small.w1", (256, 256), 256)
    assert not np.array_equal(w1, w3)


# ---------------------------------------------------------------------------
# model forwards
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(M.MODELS))
@pytest.mark.parametrize("batch", [1, 4])
def test_forward_matches_reference(name, batch):
    spec = M.MODELS[name]
    ws = [jnp.asarray(w) for w in M.init_weights(spec)]
    x = jnp.asarray(M.gen_input((batch, spec.d_in)))
    out = spec.forward(x, ws)
    pairs = [(ws[i], ws[i + 1]) for i in range(0, len(ws), 2)]
    if spec.kind == "mlp":
        ref = R.mlp_ref(x, pairs)
    else:
        ref = R.gemmnet_ref(x, pairs[:-1], pairs[-1])
    assert out.shape == (batch, spec.d_out)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name", sorted(M.MODELS))
def test_param_counts(name):
    spec = M.MODELS[name]
    ws = M.init_weights(spec)
    assert sum(w.size for w in ws) == M.param_count(spec)
    # spot-check one by hand
    if name == "mlp_small":
        assert M.param_count(spec) == 256 * 256 + 256 + 256 * 256 + 256 + 256 * 64 + 64


@pytest.mark.parametrize("name", sorted(M.MODELS))
def test_flops_positive_and_consistent(name):
    spec = M.MODELS[name]
    f = spec.flops_per_query()
    # FLOPs ~ 2 * params for GEMM-only nets (biases negligible)
    assert 1.8 * M.param_count(spec) < f <= 2.0 * M.param_count(spec) + 1

def test_batch_variants_cover_all_models():
    assert set(M.BATCH_VARIANTS) == set(M.MODELS)
    for name, bs in M.BATCH_VARIANTS.items():
        assert bs == tuple(sorted(bs)) and bs[0] == 1
        # powers of two so the dynamic batcher's pad-up rule is cheap
        assert all(b & (b - 1) == 0 for b in bs)


def test_weight_tensor_order_is_stable():
    """The flat parameter order is the rust runtime's ABI — pin it."""
    spec = M.MODELS["gemmnet6"]
    names = [nm for nm, _, _ in spec.weight_tensors()]
    assert names[0] == "gemmnet6.blk0.w"
    assert names[1] == "gemmnet6.blk0.b"
    assert names[-2] == "gemmnet6.head.w"
    assert names[-1] == "gemmnet6.head.b"
    assert len(names) == 2 * 6 + 2
