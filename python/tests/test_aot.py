"""AOT pipeline tests: manifest schema, weight blob layout, HLO text
properties, and golden consistency. Uses a tmpdir build of a small subset so
the suite stays fast; the full build is exercised by `make artifacts`."""

import json
import os
import struct

import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(out), only="mlp_small", quiet=True)
    return str(out), manifest


def test_manifest_schema(built):
    outdir, man = built
    assert man["version"] == 1 and man["input_scheme"] == "hash01"
    with open(os.path.join(outdir, "manifest.json")) as f:
        ondisk = json.load(f)
    assert ondisk == man
    (entry,) = man["models"]
    assert entry["name"] == "mlp_small"
    assert entry["d_in"] == 256 and entry["d_out"] == 64
    assert entry["params"] == M.param_count(M.MODELS["mlp_small"])
    assert len(entry["artifacts"]) == len(M.BATCH_VARIANTS["mlp_small"])


def test_hlo_text_is_parseable_hlo(built):
    outdir, man = built
    art = man["models"][0]["artifacts"][0]
    with open(os.path.join(outdir, art["file"])) as f:
        text = f.read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # weights are parameters, not constants: the entry layout declares
    # 1 input + 6 weight params (+ 1 output tuple element) = 8 f32 shapes
    header = text.splitlines()[0]
    assert header.count("f32[") == 8, header


def test_weight_blob_layout(built):
    outdir, man = built
    entry = man["models"][0]
    blob = open(os.path.join(outdir, entry["weights_file"]), "rb").read()
    total = sum(w["nbytes"] for w in entry["weights"])
    assert len(blob) == total == entry["params"] * 4
    # offsets are contiguous and in declared order
    off = 0
    for w in entry["weights"]:
        assert w["offset_bytes"] == off
        off += w["nbytes"]
    # first float of w0 equals the deterministic generator's output
    (v,) = struct.unpack("<f", blob[:4])
    assert v == pytest.approx(0.0784961134, rel=1e-6)


def test_golden_entries_are_finite_and_nontrivial(built):
    _, man = built
    for art in man["models"][0]["artifacts"]:
        g = art["golden"]
        assert len(g["out_prefix"]) == 8
        assert all(np.isfinite(g["out_prefix"]))
        assert g["out_mean_abs"] > 1e-4  # signal, not a dead model


def test_golden_matches_pallas_forward(built):
    """manifest goldens are computed through the pure-jnp reference; the
    pallas forward must agree — closing the kernel<->ref<->artifact loop."""
    import jax.numpy as jnp

    _, man = built
    spec = M.MODELS["mlp_small"]
    ws = [jnp.asarray(w) for w in M.init_weights(spec)]
    art = next(a for a in man["models"][0]["artifacts"] if a["batch"] == 2)
    x = jnp.asarray(M.gen_input((2, spec.d_in)))
    out = np.asarray(spec.forward(x, ws)).reshape(-1)
    np.testing.assert_allclose(out[:8], art["golden"]["out_prefix"], rtol=1e-4, atol=1e-5)
    assert float(np.abs(out).mean()) == pytest.approx(
        art["golden"]["out_mean_abs"], rel=1e-3
    )


def test_super_build_and_golden(tmp_path):
    man = aot.build(str(tmp_path), only="A", quiet=True)
    assert not man["models"]
    supers = man["supers"]
    assert [s["problems"] for s in supers] == [1, 2, 4, 8]
    for s in supers:
        assert s["m"] == 32 and s["k"] == 256 and s["n"] == 256
        assert os.path.exists(os.path.join(tmp_path, s["file"]))
        assert len(s["golden"]["out_prefix"]) == 8
    # golden must be reproducible from the documented hash01 bases
    import jax.numpy as jnp

    from compile.kernels import ref as R

    s = supers[1]
    p, m, k, n = s["problems"], s["m"], s["k"], s["n"]
    a = M.hash01(np.arange(p * m * k), base=aot.SUPER_A_BASE).reshape(p, m, k)
    b = M.hash01(np.arange(p * k * n), base=aot.SUPER_B_BASE).reshape(p, k, n)
    out = np.asarray(R.coalesced_matmul_ref(jnp.asarray(a), jnp.asarray(b))).reshape(-1)
    np.testing.assert_allclose(out[:8], s["golden"]["out_prefix"], rtol=1e-5)
