"""Kernel-vs-reference correctness: the CORE numeric signal of the stack.

Every pallas kernel is checked against its pure-jnp oracle across problem
counts, shapes, tile configs and activations. Tolerances: f32 paths must
match to ~1e-5 relative (same accumulation order up to tiling).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import model as M
from compile.kernels import (
    CONFIGS,
    BlockConfig,
    coalesced_matmul,
    fused_linear,
    mxu_utilization_estimate,
    resolve_tiles,
)
from compile.kernels import ref as R


def _mk(shape, base=0):
    return jnp.asarray(M.hash01(np.arange(int(np.prod(shape))), base=base).reshape(shape))


# ---------------------------------------------------------------------------
# coalesced_matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p", [1, 2, 3, 5, 8])
def test_coalesced_matmul_problem_counts(p):
    a, b = _mk((p, 16, 64)), _mk((p, 64, 32), base=9)
    out = coalesced_matmul(a, b, config="tiny")
    np.testing.assert_allclose(out, R.coalesced_matmul_ref(a, b), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("cfg", sorted(CONFIGS))
def test_coalesced_matmul_configs_match(cfg):
    a, b = _mk((2, 64, 256)), _mk((2, 256, 128), base=3)
    out = coalesced_matmul(a, b, config=cfg)
    np.testing.assert_allclose(out, R.coalesced_matmul_ref(a, b), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "m,k,n",
    [(8, 32, 8), (32, 256, 256), (32, 512, 512), (64, 1024, 1024), (1, 256, 64), (128, 128, 128)],
)
def test_coalesced_matmul_class_shapes(m, k, n):
    """Covers the manifest's superkernel classes A/B/C plus edge sizes."""
    a, b = _mk((2, m, k)), _mk((2, k, n), base=1 << 20)
    out = coalesced_matmul(a, b)
    np.testing.assert_allclose(out, R.coalesced_matmul_ref(a, b), rtol=1e-4, atol=1e-4)


def test_coalesced_matmul_bf16_inputs_accumulate_f32():
    a = _mk((2, 32, 128)).astype(jnp.bfloat16)
    b = _mk((2, 128, 64), base=5).astype(jnp.bfloat16)
    out = coalesced_matmul(a, b)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(
        out, R.coalesced_matmul_ref(a, b), rtol=2e-2, atol=2e-2
    )


def test_coalesced_matmul_problems_are_independent():
    """VLIW packing invariant: packing must not change any problem's result —
    computing problems together == computing each alone."""
    a, b = _mk((4, 16, 64)), _mk((4, 64, 32), base=11)
    packed = coalesced_matmul(a, b, config="tiny")
    for i in range(4):
        alone = coalesced_matmul(a[i : i + 1], b[i : i + 1], config="tiny")
        np.testing.assert_allclose(packed[i], alone[0], rtol=1e-6, atol=1e-6)


def test_coalesced_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        coalesced_matmul(_mk((2, 8, 16)), _mk((3, 16, 8)))
    with pytest.raises(ValueError):
        coalesced_matmul(_mk((2, 8, 16)), _mk((2, 32, 8)))
    with pytest.raises(ValueError):
        coalesced_matmul(_mk((8, 16)), _mk((16, 8)))


# ---------------------------------------------------------------------------
# fused_linear
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("act", ["none", "relu", "gelu"])
def test_fused_linear_activations(act):
    x, w, b = _mk((8, 128)), _mk((128, 64), base=2), _mk((64,), base=4)
    out = fused_linear(x, w, b, act=act)
    np.testing.assert_allclose(
        out, R.fused_linear_ref(x, w, b, act=act), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("batch", [1, 2, 3, 7, 16, 32])
def test_fused_linear_ragged_batches(batch):
    """Batches that don't divide the tile: resolve_tiles must degrade
    gracefully (tm falls back to a divisor)."""
    x, w, b = _mk((batch, 256)), _mk((256, 64), base=8), _mk((64,), base=1)
    out = fused_linear(x, w, b, act="relu")
    np.testing.assert_allclose(
        out, R.fused_linear_ref(x, w, b, act="relu"), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("cfg", sorted(CONFIGS))
def test_fused_linear_config_invariant(cfg):
    """Tiling must be value-invariant: all configs produce the same y."""
    x, w, b = _mk((16, 512)), _mk((512, 256), base=6), _mk((256,), base=3)
    out = fused_linear(x, w, b, act="relu", config=cfg)
    np.testing.assert_allclose(
        out, R.fused_linear_ref(x, w, b, act="relu"), rtol=1e-5, atol=1e-5
    )


def test_fused_linear_rejects_bad_activation():
    x, w, b = _mk((4, 32)), _mk((32, 16)), _mk((16,))
    with pytest.raises(ValueError):
        fused_linear(x, w, b, act="swish")


def test_fused_linear_rejects_shape_mismatch():
    with pytest.raises(ValueError):
        fused_linear(_mk((4, 32)), _mk((64, 16)), _mk((16,)))


# ---------------------------------------------------------------------------
# blocking configs
# ---------------------------------------------------------------------------


def test_resolve_tiles_divides():
    for m, n, k in [(3, 7, 5), (32, 256, 512), (1, 1, 1), (48, 96, 192)]:
        cfg = resolve_tiles(m, n, k, CONFIGS["greedy"])
        assert m % cfg.tm == 0 and n % cfg.tn == 0 and k % cfg.tk == 0
        assert cfg.tm <= 128 and cfg.tn <= 128 and cfg.tk <= 512


def test_vmem_budget_under_ceiling():
    """All named configs must fit well under a 16 MiB VMEM ceiling, with
    2x headroom for double-buffering."""
    for name, cfg in CONFIGS.items():
        assert 2 * cfg.vmem_bytes() < 16 * 1024 * 1024, name


def test_greedy_config_has_full_mxu_utilization():
    assert mxu_utilization_estimate(CONFIGS["greedy"]) == pytest.approx(1.0)
    # collaborative trades utilization for co-residency
    assert mxu_utilization_estimate(CONFIGS["collaborative"]) < 1.0


def test_config_vmem_ordering():
    """The collaborative config must be strictly lighter than greedy — that
    is its entire reason to exist (Table 1)."""
    assert CONFIGS["collaborative"].vmem_bytes() < CONFIGS["greedy"].vmem_bytes()
