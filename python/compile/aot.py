"""AOT compile path: lower every (model, batch) variant and every superkernel
variant to HLO *text* + write `manifest.json` + weight blobs.

Run once by `make artifacts` (python is never on the request path):

    cd python && python -m compile.aot --outdir ../artifacts

Interchange format is HLO TEXT, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the rust side's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md). Lowered with
return_tuple=True; the rust runtime unwraps with `to_tuple1()`.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import coalesced_matmul
from .kernels import ref as R

# Superkernel shape-classes (Fig. 7 clusters A/B/C, M scaled for CPU
# tractability — class M in the paper includes im2col rows in the 10^3
# range; the *packing semantics* are M-invariant).
SUPER_CLASSES = {
    "A": dict(m=32, k=256, n=256, problems=(1, 2, 4, 8)),
    "B": dict(m=32, k=512, n=512, problems=(1, 2, 4, 8)),
    "C": dict(m=64, k=1024, n=1024, problems=(1, 2, 4)),
}

#: hash01 stream bases for superkernel golden inputs (mirrored in rust).
SUPER_A_BASE = 0
SUPER_B_BASE = 1 << 20


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(spec, batch: int, weights) -> str:
    """Lower spec.forward at a fixed batch to HLO text. Inputs are
    (x, *weights) — weights are runtime parameters, not constants."""

    def fn(x, *flat):
        return (spec.forward(x, flat),)

    x_spec = jax.ShapeDtypeStruct((batch, spec.d_in), jnp.float32)
    w_specs = [jax.ShapeDtypeStruct(w.shape, jnp.float32) for w in weights]
    lowered = jax.jit(fn).lower(x_spec, *w_specs)
    return to_hlo_text(lowered)


def lower_super(m: int, k: int, n: int, problems: int) -> str:
    """Lower the raw coalesced-GEMM superkernel at a fixed capacity."""

    def fn(a, b):
        return (coalesced_matmul(a, b, config="greedy"),)

    a_spec = jax.ShapeDtypeStruct((problems, m, k), jnp.float32)
    b_spec = jax.ShapeDtypeStruct((problems, k, n), jnp.float32)
    lowered = jax.jit(fn).lower(a_spec, b_spec)
    return to_hlo_text(lowered)


def model_golden(spec, batch: int, weights) -> dict:
    """Golden vector via the pure-jnp reference (NOT the pallas path), so the
    rust end-to-end check transitively validates kernel-vs-ref too."""
    x = M.gen_input((batch, spec.d_in))
    pairs = [(weights[i], weights[i + 1]) for i in range(0, len(weights), 2)]
    if spec.kind == "mlp":
        out = R.mlp_ref(jnp.asarray(x), pairs)
    else:
        out = R.gemmnet_ref(jnp.asarray(x), pairs[:-1], pairs[-1])
    flat = np.asarray(out).reshape(-1)
    return {
        "out_prefix": [float(v) for v in flat[:8]],
        "out_mean_abs": float(np.abs(flat).mean()),
    }


def super_golden(m: int, k: int, n: int, problems: int) -> dict:
    a = M.hash01(np.arange(problems * m * k), base=SUPER_A_BASE).reshape(problems, m, k)
    b = M.hash01(np.arange(problems * k * n), base=SUPER_B_BASE).reshape(problems, k, n)
    out = np.asarray(R.coalesced_matmul_ref(jnp.asarray(a), jnp.asarray(b))).reshape(-1)
    return {
        "out_prefix": [float(v) for v in out[:8]],
        "out_mean_abs": float(np.abs(out).mean()),
    }


def write_weights(outdir: str, spec, weights) -> tuple[str, list[dict]]:
    """Concatenate weights (f32 LE raw) into <model>.weights.bin."""
    fname = f"{spec.name}.weights.bin"
    table, off = [], 0
    with open(os.path.join(outdir, fname), "wb") as f:
        for (nm, shp, _), w in zip(spec.weight_tensors(), weights):
            raw = np.ascontiguousarray(w, dtype="<f4").tobytes()
            table.append(
                {"name": nm, "shape": list(shp), "offset_bytes": off, "nbytes": len(raw)}
            )
            f.write(raw)
            off += len(raw)
    return fname, table


def build(outdir: str, only: str | None = None, quiet: bool = False) -> dict:
    os.makedirs(outdir, exist_ok=True)
    t0 = time.time()
    manifest: dict = {
        "version": 1,
        "generator": "compile.aot",
        "input_scheme": "hash01",
        "models": [],
        "supers": [],
    }

    for name, spec in M.MODELS.items():
        if only and only not in (name, "models"):
            continue
        weights = M.init_weights(spec)
        wfile, wtable = write_weights(outdir, spec, weights)
        entry = {
            "name": name,
            "kind": spec.kind,
            "d_in": spec.d_in,
            "d_out": spec.d_out,
            "params": M.param_count(spec),
            "flops_per_query": spec.flops_per_query(),
            "weights_file": wfile,
            "weights": wtable,
            "artifacts": [],
        }
        for b in M.BATCH_VARIANTS[name]:
            fname = f"{name}_b{b}.hlo.txt"
            hlo = lower_model(spec, b, weights)
            with open(os.path.join(outdir, fname), "w") as f:
                f.write(hlo)
            entry["artifacts"].append(
                {"batch": b, "file": fname, "golden": model_golden(spec, b, weights)}
            )
            if not quiet:
                print(f"  [aot] {fname}  ({len(hlo)} chars)", flush=True)
        manifest["models"].append(entry)

    for cls, cfg in SUPER_CLASSES.items():
        if only and only not in (cls, "supers"):
            continue
        for p in cfg["problems"]:
            fname = f"super_{cls}_p{p}.hlo.txt"
            hlo = lower_super(cfg["m"], cfg["k"], cfg["n"], p)
            with open(os.path.join(outdir, fname), "w") as f:
                f.write(hlo)
            manifest["supers"].append(
                {
                    "class": cls,
                    "m": cfg["m"],
                    "k": cfg["k"],
                    "n": cfg["n"],
                    "problems": p,
                    "file": fname,
                    "golden": super_golden(cfg["m"], cfg["k"], cfg["n"], p),
                }
            )
            if not quiet:
                print(f"  [aot] {fname}  ({len(hlo)} chars)", flush=True)

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if not quiet:
        n_art = sum(len(m["artifacts"]) for m in manifest["models"]) + len(
            manifest["supers"]
        )
        print(f"[aot] wrote {n_art} artifacts + manifest in {time.time()-t0:.1f}s")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--only", default=None, help="model name / super class filter")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    build(args.outdir, only=args.only, quiet=args.quiet)


if __name__ == "__main__":
    main()
