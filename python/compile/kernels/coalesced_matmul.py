"""L1 Pallas superkernel: coalesced (VLIW-packed) batched GEMM.

This is the compute hot-spot of the paper: `cublasSgemmBatched`-style
co-execution of P *independent* GEMM problems, one per coalesced stream of
execution, rethought for TPU/Pallas:

  * CUDA threadblock packing across SMs  ->  grid dimension 0 is the
    *problem index*: one grid program per (problem, m-tile, n-tile), which is
    exactly how cublasSgemmBatched assigns thread blocks per batch entry.
  * shared-memory tiling                 ->  VMEM BlockSpec tiling: each grid
    step pulls a (tm x tk) A-slab and a (tk x tn) B-slab into VMEM.
  * tensor-core WMMA                     ->  MXU systolic matmul; tiles are
    chosen as multiples of 128 where shapes allow (the paper's "minimal
    padding within a cluster" argument at MXU granularity).

VMEM budget per grid step (f32): 4*(tm*tk + tk*tn + tm*tn) bytes. The
default "greedy" config (tm=tn=128, tk=512) uses 4*(64K+64K+16K) = 576 KiB,
far under the ~16 MiB VMEM ceiling, leaving headroom for double-buffering.
The "collaborative" config (Table 1) deliberately shrinks tiles to leave
room for co-resident kernels; see `CONFIGS` below.

Pallas is ALWAYS invoked with interpret=True here: the CPU PJRT plugin used
by the rust runtime cannot execute Mosaic custom-calls, so the kernel is
lowered to plain HLO through the interpreter path. Real-TPU performance is
estimated analytically in DESIGN.md / EXPERIMENTS.md (see "SS-Perf").
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@dataclass(frozen=True)
class BlockConfig:
    """A blocking (auto-tuning) configuration for the superkernel.

    Mirrors `compiler::autotune::LaunchConfig` on the rust side: the AOT
    autotuner picks one of these per shape-class, and the JIT applies it
    when forming superkernels.
    """

    tm: int  # rows of the A/output tile resident in VMEM
    tn: int  # cols of the B/output tile resident in VMEM
    tk: int  # contraction slab; K is looped in steps of tk

    def vmem_bytes(self, dtype_bytes: int = 4) -> int:
        """Per-step VMEM residency: A-slab + B-slab + accumulator tile."""
        return dtype_bytes * (
            self.tm * self.tk + self.tk * self.tn + self.tm * self.tn
        )


#: Named configurations referenced by the autotuner (Table 1). "greedy"
#: maximizes isolated MXU utilization with the largest tiles; "collaborative"
#: trades ~20% isolated throughput for smaller VMEM/SM residency so that
#: co-scheduled kernels overlap (1.25x multiplexed throughput in the paper).
CONFIGS: dict[str, BlockConfig] = {
    "greedy": BlockConfig(tm=128, tn=128, tk=512),
    "collaborative": BlockConfig(tm=64, tn=64, tk=256),
    "tiny": BlockConfig(tm=8, tn=8, tk=32),  # exercises multi-step grids in tests
}


def _pick(dim: int, want: int) -> int:
    """Largest tile <= `want` that divides `dim` (shapes here are padded by
    the coalescer to powers of two, so this terminates quickly)."""
    t = min(want, dim)
    while dim % t != 0:
        t -= 1
    return t


def resolve_tiles(m: int, n: int, k: int, config: BlockConfig) -> BlockConfig:
    """Clamp a config to tiles that evenly divide the (padded) problem."""
    return BlockConfig(
        tm=_pick(m, config.tm), tn=_pick(n, config.tn), tk=_pick(k, config.tk)
    )


def _mm_kernel(a_ref, b_ref, o_ref, *, nk: int):
    """Grid body: accumulate one (tm x tk) @ (tk x tn) product into the
    output tile.

    Grid is (P, M/tm, N/tn, K/tk); the K axis is innermost, so the output
    block for a given (p, i, j) stays resident across K steps and serves as
    the accumulator (f32), exactly the revisiting-output pattern Mosaic
    double-buffers on real TPUs.
    """
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[0, ...] += jnp.dot(
        a_ref[0], b_ref[0], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def coalesced_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    config: BlockConfig | str = "greedy",
) -> jax.Array:
    """Execute P independent GEMMs as one superkernel.

    Args:
      a: [P, M, K] — P left operands, one per coalesced problem.
      b: [P, K, N] — P right operands.
      config: blocking configuration (name from CONFIGS or a BlockConfig).

    Returns:
      [P, M, N] f32 — the P products, computed in a single pallas_call whose
      grid packs all problems (the VLIW "long instruction word").
    """
    if isinstance(config, str):
        config = CONFIGS[config]
    if a.ndim != 3 or b.ndim != 3:
        raise ValueError(f"expected [P,M,K] and [P,K,N], got {a.shape} and {b.shape}")
    p, m, k = a.shape
    pb, kb, n = b.shape
    if pb != p or kb != k:
        raise ValueError(f"operand mismatch: a={a.shape} b={b.shape}")
    cfg = resolve_tiles(m, n, k, config)
    nk = k // cfg.tk
    grid = (p, m // cfg.tm, n // cfg.tn, nk)

    return pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cfg.tm, cfg.tk), lambda pi, i, j, ki: (pi, i, ki)),
            pl.BlockSpec((1, cfg.tk, cfg.tn), lambda pi, i, j, ki: (pi, ki, j)),
        ],
        out_specs=pl.BlockSpec((1, cfg.tm, cfg.tn), lambda pi, i, j, ki: (pi, i, j)),
        out_shape=jax.ShapeDtypeStruct((p, m, n), jnp.float32),
        interpret=True,
    )(a, b)


def mxu_utilization_estimate(config: BlockConfig) -> float:
    """Analytic MXU utilization estimate for a tile config (TPU target).

    The MXU consumes 128x128 operand tiles; a (tm x tn) output tile built
    from tk-deep slabs achieves util = coverage(tm) * coverage(tn) *
    coverage(tk), where coverage(t) = t / (128 * ceil(t/128)). This is the
    number DESIGN.md SS-Perf reports — interpret-mode wallclock is NOT a TPU
    proxy, so structure is optimized instead of CPU timing.
    """

    def cov(t: int) -> float:
        return t / (128.0 * math.ceil(t / 128.0))

    return cov(config.tm) * cov(config.tn) * cov(config.tk)
