"""L1 Pallas kernel: fused linear layer  y = act(x @ W + b).

The per-layer building block of the L2 models. Fusing the bias add and
activation into the GEMM epilogue removes two HBM round-trips per layer —
the standard inference-serving fusion (cuBLASLt epilogue / TensorRT fused
ops in the paper's world; on TPU the VPU applies the epilogue while the
output tile is still resident in VMEM).

Grid is (M/tm, N/tn, K/tk) with the K axis innermost; the output tile is
the accumulator (revisited across K steps), and the epilogue fires on the
last K step only. interpret=True throughout — see coalesced_matmul.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .coalesced_matmul import CONFIGS, BlockConfig, resolve_tiles

#: Supported epilogue activations, by name (manifest-stable identifiers).
ACTIVATIONS = ("none", "relu", "gelu")


def _apply_act(x: jax.Array, act: str) -> jax.Array:
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "gelu":
        return jax.nn.gelu(x)
    return x


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, act: str):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)

    @pl.when(ki == nk - 1)
    def _epilogue():
        o_ref[...] = _apply_act(o_ref[...] + b_ref[...], act)


def fused_linear(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    act: str = "relu",
    config: BlockConfig | str = "greedy",
) -> jax.Array:
    """y = act(x @ w + b) as a single Pallas kernel.

    Args:
      x: [M, K] activations (M = padded batch).
      w: [K, N] weights.
      b: [N] bias.
      act: epilogue activation, one of ACTIVATIONS.
      config: blocking configuration (see coalesced_matmul.CONFIGS).

    Returns: [M, N] f32.
    """
    if isinstance(config, str):
        config = CONFIGS[config]
    if act not in ACTIVATIONS:
        raise ValueError(f"unknown activation {act!r}; expected one of {ACTIVATIONS}")
    m, k = x.shape
    kw, n = w.shape
    if kw != k or b.shape != (n,):
        raise ValueError(f"shape mismatch: x={x.shape} w={w.shape} b={b.shape}")
    cfg = resolve_tiles(m, n, k, config)
    nk = k // cfg.tk
    grid = (m // cfg.tm, n // cfg.tn, nk)

    return pl.pallas_call(
        functools.partial(_linear_kernel, nk=nk, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((cfg.tm, cfg.tk), lambda i, j, ki: (i, ki)),
            pl.BlockSpec((cfg.tk, cfg.tn), lambda i, j, ki: (ki, j)),
            pl.BlockSpec((cfg.tn,), lambda i, j, ki: (j,)),
        ],
        out_specs=pl.BlockSpec((cfg.tm, cfg.tn), lambda i, j, ki: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)
