"""Pallas kernels (L1) and their pure-jnp oracles.

Build-time only: these lower into the model HLO via `compile.aot`; nothing
here is imported by the rust request path.
"""

from .coalesced_matmul import (  # noqa: F401
    CONFIGS,
    BlockConfig,
    coalesced_matmul,
    mxu_utilization_estimate,
    resolve_tiles,
)
from .fused_linear import ACTIVATIONS, fused_linear  # noqa: F401
