"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every Pallas kernel in this package has a reference implementation here
written with nothing but jax.numpy; pytest asserts allclose between the two
across shape/dtype/config sweeps (see python/tests/). The rust runtime's
numerics are in turn validated against golden vectors computed through
these references at AOT time (manifest `golden` entries).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def coalesced_matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """[P,M,K] x [P,K,N] -> [P,M,N], f32 accumulation."""
    return jnp.einsum(
        "pmk,pkn->pmn", a.astype(jnp.float32), b.astype(jnp.float32)
    ).astype(jnp.float32)


def fused_linear_ref(
    x: jax.Array, w: jax.Array, b: jax.Array, *, act: str = "relu"
) -> jax.Array:
    """act(x @ w + b), f32."""
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32)
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "gelu":
        y = jax.nn.gelu(y)
    elif act != "none":
        raise ValueError(f"unknown activation {act!r}")
    return y


def mlp_ref(x: jax.Array, weights: list[tuple[jax.Array, jax.Array]]) -> jax.Array:
    """Reference MLP forward: relu on hidden layers, identity on the head."""
    h = x
    for li, (w, b) in enumerate(weights):
        act = "none" if li == len(weights) - 1 else "relu"
        h = fused_linear_ref(h, w, b, act=act)
    return h


def gemmnet_ref(
    x: jax.Array,
    blocks: list[tuple[jax.Array, jax.Array]],
    head: tuple[jax.Array, jax.Array],
) -> jax.Array:
    """Reference residual-GEMM network: h = h + relu(h @ W + b) per block."""
    h = x.astype(jnp.float32)
    for w, b in blocks:
        h = h + fused_linear_ref(h, w, b, act="relu")
    hw, hb = head
    return fused_linear_ref(h, hw, hb, act="none")
